"""Serving example: batched greedy decoding + streaming prefill batches
dispatched across heterogeneous replicas.

A real (small) model serves batches of requests; then prefill batches
*arrive continuously* and are queued and dispatched across K
heterogeneous serving replicas by the streaming-arrival engine
(``repro.serving``) -- the paper's schemes recast as dispatch policies,
compared on tail latency and SLO misses at a fixed offered load.

With ``--live`` the same batch also *executes* over the async control
plane (``repro.control``): real transport round-trips and jitted matmul
shards on each replica, measured T_comp printed next to the MC
prediction per policy.

Run:  PYTHONPATH=src python examples/serve_batch.py [--live]
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.types import HetSpec
from repro.models import build_model
from repro.serving import ServingConfig, simulate_serving
from repro.train.serve import greedy_generate


def run_live_batch(het, N, policies):
    """One arriving batch executed for real per policy: live episodes
    through the coordinator vs the MC prediction at the same point."""
    from repro.control import LiveConfig, run_live
    from repro.core.schemes import get_scheme

    cfg = LiveConfig(target_wall_s=0.3)
    print(f"\nexecuting one {N}-request batch live "
          f"(inproc transport, jitted shards) per policy:")
    for policy in policies:
        rep = run_live(policy, {}, het, N, cfg, trials=2, seed=5)
        mc = get_scheme(policy).mc(het, N, 400, np.random.default_rng(0))
        cp = rep.extra["control_plane"]
        print(f"  {policy:<21} measured {rep.t_comp:6.2f}s  "
              f"MC-predicted {mc.t_comp:6.2f}s  "
              f"coordination {cp['coordination_frac']:.1%} of wall")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--live", action="store_true",
                    help="also execute the batch over the async control "
                         "plane (repro.control) and print measured vs "
                         "MC-predicted T_comp")
    args = ap.parse_args()
    cfg = dataclasses.replace(smoke_config(get_config("phi4-mini-3.8b")),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    # --- batched generation (real decode path with KV cache) --------------
    B, S_prompt, steps = 4, 16, 12
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_prompt)),
                          jnp.int32)
    cache = model.init_cache(B, S_prompt + steps)
    toks, _ = greedy_generate(model, params, {"tokens": prompts}, cache,
                              steps)
    print(f"generated {toks.shape[1]} tokens for {B} requests "
          f"(greedy, KV-cached):")
    print(np.asarray(toks)[:, :10])

    # --- streaming prefill batches through the serving engine -------------
    rates = np.array([2.0, 7.0, 3.0, 11.0])   # prefill throughput/replica
    het = HetSpec(rates)
    N = 40                       # prefill requests per arriving batch job
    load = 0.8                   # offered fraction of aggregate capacity
    cfg = ServingConfig(loads=(load,), slots=1500, deadline_slo=4.0)
    print(f"\nstreaming prefill batches ({N} requests each) over "
          f"{len(rates)} heterogeneous replicas at {load:.0%} load:")
    for policy in ("work_exchange", "work_exchange_unknown", "fixed",
                   "uniform"):
        rep = simulate_serving(het, policy, {}, cfg, N, load, trials=8,
                               rng=np.random.default_rng(3))
        e = rep.extra
        print(f"  {policy:<21} sojourn {rep.t_comp:6.2f}s  "
              f"p99 {e['p99']:6.2f}s  "
              f"throughput {e['throughput_jobs']:.2f} jobs/s  "
              f"SLO-miss {e['slo_miss_rate']:.0%}")
    print("  (work_exchange_unknown learns replica rates online; uniform "
          "ignores heterogeneity)")

    if args.live:
        run_live_batch(het, N, ("work_exchange", "work_exchange_unknown",
                                "fixed", "uniform"))


if __name__ == "__main__":
    main()
