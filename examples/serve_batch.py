"""Serving example: batched greedy decoding + heterogeneity-aware request
scheduling across replicas.

A real (small) model serves batches of requests; the prefill work for a
queue of requests is distributed across K heterogeneous serving replicas
with the work-exchange scheduler -- the paper's technique applied to the
serving plane (requests are the units).

Run:  PYTHONPATH=src python examples/serve_batch.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, smoke_config
from repro.core.exchange import MasterScheduler
from repro.core.runtime import VirtualWorkerPool
from repro.models import build_model
from repro.train.serve import greedy_generate


def main():
    cfg = dataclasses.replace(smoke_config(get_config("phi4-mini-3.8b")),
                              dtype="float32")
    model = build_model(cfg)
    params = model.init(jax.random.key(0))

    # --- batched generation (real decode path with KV cache) --------------
    B, S_prompt, steps = 4, 16, 12
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_prompt)),
                          jnp.int32)
    cache = model.init_cache(B, S_prompt + steps)
    toks, _ = greedy_generate(model, params, {"tokens": prompts}, cache,
                              steps)
    print(f"generated {toks.shape[1]} tokens for {B} requests "
          f"(greedy, KV-cached):")
    print(np.asarray(toks)[:, :10])

    # --- heterogeneity-aware request scheduling ---------------------------
    n_requests = 400
    rates = np.array([2.0, 7.0, 3.0, 11.0])   # prefill throughput/replica
    sched = MasterScheduler(range(n_requests), K=len(rates), rates=None,
                            threshold_frac=0.02)
    pool = VirtualWorkerPool(rates, seed=3)
    while not sched.finished:
        a = sched.next_assignment()
        if a is None:
            break
        elapsed, done = pool.run_epoch(a)
        sched.report(done, elapsed)
    oracle = n_requests / rates.sum()
    print(f"\nprefill queue of {n_requests} requests over "
          f"{len(rates)} heterogeneous replicas:")
    print(f"  work-exchange completion: {sched.t_comp:.2f}s "
          f"(oracle {oracle:.2f}s, +{100 * (sched.t_comp / oracle - 1):.1f}%)")
    print(f"  reassignment rounds: {sched.iterations}, "
          f"requests moved: {sched.n_comm}")
    print(f"  learned replica rates: "
          f"{np.round(sched.estimated_rates(), 2)} (true {rates})")


if __name__ == "__main__":
    main()
