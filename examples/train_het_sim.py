"""End-to-end training driver: a real model trained to convergence under
every scheduling policy, with per-policy virtual completion times.

Default is a CPU-sized model (~15M params); ``--scale 100m`` selects the
~100M-parameter configuration (same code path; sized for a real pod).
Checkpoints + restart supported (kill and re-run with the same --ckpt).

Run:  PYTHONPATH=src python examples/train_het_sim.py --steps 60
"""
import argparse
import dataclasses
import time

import jax
import numpy as np

from repro.checkpoint import (latest_checkpoint, restore_checkpoint,
                              save_checkpoint)
from repro.configs import get_config, smoke_config
from repro.data import UnitStore
from repro.distributed.hetsched import POLICIES, HetTrainer
from repro.models import build_model
from repro.optim import AdamW

SCALES = {
    # ~15M params: CPU-friendly demo
    "15m": dict(n_layers=4, d_model=256, n_heads=8, head_dim=32,
                n_kv_heads=4, d_ff=1024, vocab_size=8192),
    # ~100M params: the assignment's e2e target (pod-sized)
    "100m": dict(n_layers=12, d_model=768, n_heads=12, head_dim=64,
                 n_kv_heads=4, d_ff=3072, vocab_size=32768),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--scale", choices=SCALES, default="15m")
    ap.add_argument("--policy", choices=POLICIES, default=None,
                    help="default: compare all policies")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--units", type=int, default=8)
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--fail-worker", type=int, default=None,
                    help="kill this worker at step 5 (fault-tolerance demo)")
    args = ap.parse_args()

    base = smoke_config(get_config("phi3-mini-3.8b"))
    cfg = dataclasses.replace(base, dtype="float32", **SCALES[args.scale])
    model = build_model(cfg)
    params0 = model.init(jax.random.key(0))
    n_params = sum(x.size for x in jax.tree.leaves(params0))
    print(f"model: {n_params / 1e6:.1f}M params | seq {args.seq} "
          f"| {args.units} units/step")

    rates = np.array([1.0, 3.0, 5.0, 9.0, 2.0, 6.0, 4.0, 8.0])
    store = UnitStore(unit_batch=2, seq_len=args.seq, vocab=cfg.vocab_size,
                      structured=True)
    policies = [args.policy] if args.policy else \
        ["equal_static", "het_static", "work_exchange",
         "work_exchange_online", "gradient_coded"]

    failures = {5: [args.fail_worker]} if args.fail_worker is not None else {}
    summary = []
    for policy in policies:
        trainer = HetTrainer(model, AdamW(lr=3e-3, weight_decay=0.0),
                             rates, store, policy=policy,
                             units_per_step=args.units, seed=11)
        params = params0
        opt_state = trainer.opt.init(params)
        start = 0
        if args.ckpt:
            ck = latest_checkpoint(f"{args.ckpt}/{policy}")
            if ck:
                (params, opt_state), extra = restore_checkpoint(
                    ck, (params, opt_state))
                start = extra["step"] + 1
                print(f"[{policy}] resumed from step {start}")
        t0 = time.time()
        hist = []
        for s in range(start, args.steps):
            params, opt_state, rep = trainer.step(
                params, opt_state, s, failures.get(s, ()))
            hist.append(rep)
            if args.ckpt and s % 20 == 19:
                save_checkpoint(f"{args.ckpt}/{policy}", s,
                                (params, opt_state), extra={"step": s})
            if s % 10 == 0:
                print(f"[{policy}] step {s}: loss={rep.loss:.3f} "
                      f"T={rep.t_virtual:.3f}s I={rep.iterations}")
        t_virtual = sum(h.t_virtual for h in hist)
        summary.append((policy, hist[-1].loss if hist else float('nan'),
                        t_virtual, time.time() - t0))

    print("\npolicy                 final-loss  virtual-time   wall")
    for policy, loss, tv, wall in summary:
        print(f"{policy:22s} {loss:10.3f} {tv:12.2f}s {wall:7.1f}s")
    oracle = args.steps * args.units / rates.sum()
    print(f"{'(oracle bound)':22s} {'':10s} {oracle:12.2f}s")


if __name__ == "__main__":
    main()
