"""Roofline analysis from the dry-run compiled artifacts.

Per (arch x shape) cell on the single-pod production mesh:

  compute term    = HLO_FLOPs_per_device   / PEAK_FLOPS     [s]
  memory term     = HLO_bytes_per_device   / HBM_BW         [s]
  collective term = wire_bytes_per_device  / LINK_BW        [s]

Sources: the unroll-mode dry-run gives exact per-device cost_analysis()
FLOPs/bytes (scan bodies are counted once by XLA -- DESIGN §5.3); the
trip-count-aware HLO parse gives collective wire bytes (ring-cost model).
MODEL_FLOPS is the analytic useful work (6*N_active*D for training;
2*N_active*D for single-pass inference; causal-aware attention terms), so
MODEL_FLOPS / HLO_FLOPs exposes remat/redundancy waste, and

  roofline_fraction = (MODEL_FLOPS/chips/PEAK) / max(term_i)

is the peak-utilization bound the compiled program can reach assuming
perfect overlap -- the score tracked by EXPERIMENTS §Perf.

Hardware model (TPU v5e-like, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s per ICI link (single-link conservative).
"""
from __future__ import annotations

import glob
import json
import os
from pathlib import Path

import jax
import numpy as np

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

RESULTS = Path(os.environ.get("REPRO_RESULTS", "results/dryrun"))


# ---------------------------------------------------------------------------
# analytic MODEL_FLOPS
# ---------------------------------------------------------------------------

def _linear_params(cfg) -> tuple[float, float]:
    """(active, total) matmul-parameter counts (embedding gather excluded,
    lm_head included; MoE experts scaled by k/E for the active count)."""
    from repro.models import build_model
    model = build_model(cfg)
    shapes = model.param_specs()
    active = total = 0.0
    k_frac = (cfg.experts_per_token / cfg.n_experts) if cfg.is_moe else 1.0

    def visit(path, leaf):
        nonlocal active, total
        name = str(path[-1].key if hasattr(path[-1], "key") else path[-1])
        if leaf.ndim < 2 or name == "embed":
            return
        n = float(np.prod(leaf.shape))
        total += n
        if name in ("wi_gate", "wi_up", "wo") and leaf.ndim >= 3:
            # stacked expert weights
            active += n * k_frac * cfg.capacity_factor
        else:
            active += n

    jax.tree_util.tree_map_with_path(visit, shapes)
    return active, total


def _attn_flops(cfg, shape) -> float:
    """Forward softmax-attention matmul FLOPs (scores + PV), causal-aware."""
    B, S = shape.global_batch, shape.seq_len
    H, hd = cfg.heads, cfg.hd

    def pair_count(s, window):
        if window and window < s:
            return s * window - window * window / 2.0
        return s * s / 2.0

    total = 0.0
    kinds = cfg.layer_kinds()
    if cfg.family == "encdec":
        s_src = S // 2 if shape.kind == "train" else S
        s_tgt = S // 2 if shape.kind == "train" else 1024
        enc = cfg.n_enc_layers * 4 * B * s_src * s_src * H * hd
        dec_self = cfg.n_dec_layers * 4 * B * pair_count(s_tgt, 0) * H * hd
        cross = cfg.n_dec_layers * 4 * B * s_tgt * s_src * H * hd
        return enc + dec_self + cross
    for kind in kinds:
        if kind == "attn":
            w = cfg.window if cfg.attn_kind == "swa" or cfg.block_pattern \
                else 0
            if cfg.attn_kind == "mla":
                dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
                per = 2 * B * pair_count(S, 0) * H * (dn + dr + dv)
            else:
                per = 4 * B * pair_count(S, w) * H * hd
            total += per
        elif kind == "mlstm":
            L = 256  # chunk
            din = int(cfg.proj_factor_mlstm * cfg.d_model)
            total += 4 * B * S * L * din / 2
        # rec / slstm: recurrences are param-matmuls (already in N_active)
    return total


def _decode_attn_flops(cfg, shape) -> float:
    B, S = shape.global_batch, shape.seq_len
    H, hd = cfg.heads, cfg.hd
    total = 0.0
    for kind in cfg.layer_kinds():
        if kind == "attn":
            w = cfg.window if cfg.attn_kind == "swa" or cfg.block_pattern \
                else 0
            ctx = min(S, w) if w else S
            if cfg.attn_kind == "mla":
                total += 2 * B * ctx * H * (cfg.kv_lora_rank
                                            + cfg.qk_rope_dim
                                            + cfg.kv_lora_rank)
            else:
                total += 4 * B * ctx * H * hd
        elif kind == "mlstm":
            din = int(cfg.proj_factor_mlstm * cfg.d_model)
            dh = din // cfg.heads
            total += 4 * B * din * dh
    if cfg.family == "encdec":
        total = cfg.n_dec_layers * (4 * B * S * H * hd) * 2  # self + cross
    return total


def model_flops(cfg, shape) -> float:
    active, _ = _linear_params(cfg)
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * S
        return 6.0 * active * tokens + 3.0 * _attn_flops(cfg, shape)
    if shape.kind == "prefill":
        tokens = B * (S if cfg.family != "encdec" else S + 1024)
        return 2.0 * active * tokens + _attn_flops(cfg, shape)
    # decode: one token per sequence
    return 2.0 * active * B + _decode_attn_flops(cfg, shape)


# ---------------------------------------------------------------------------
# table assembly
# ---------------------------------------------------------------------------

def load_cell(arch: str, shape: str, mesh: str = "single"):
    base = RESULTS / f"{mesh}__{arch}__{shape}.json"
    unroll = RESULTS / f"{mesh}__{arch}__{shape}__unroll.json"
    d = json.loads(base.read_text()) if base.exists() else None
    du = json.loads(unroll.read_text()) if unroll.exists() else None
    return d, du


# calibrations measured against exact unroll-mode cost_analysis compiles
# (EXPERIMENTS §Roofline): trip-corrected dot flops understate total HLO
# flops by the elementwise share; the fusion-boundary byte census
# overstates XLA's bytes-accessed by double-counting producer/consumer.
ELEMWISE_UPLIFT = 1.10
MEM_BYTES_CALIB = 1.45


def cell_terms(arch: str, shape_name: str, mesh: str = "single"):
    from repro.configs import SHAPES, get_config, resolve_for_tp
    d, du = load_cell(arch, shape_name, mesh)
    if d is None or d.get("skipped"):
        return None
    cfg = resolve_for_tp(get_config(arch), 16)
    shape = SHAPES[shape_name]
    n_dev = d["n_devices"]
    # exact per-device flops/bytes prefer the unroll compile
    if du is not None and not du.get("skipped"):
        flops = max(du["cost_analysis"]["flops"], du["hlo"]["dot_flops"])
        bytes_hi = bytes_lo = du["cost_analysis"]["bytes_accessed"]
    else:
        flops = d["hlo"]["dot_flops"] * ELEMWISE_UPLIFT
        # bracket HBM traffic: the op-boundary census over-counts on the
        # weakly-fusing CPU backend (upper bound); body-once cost_analysis
        # under-counts scanned layers (lower bound).  Point estimate =
        # geometric mean of the bracket.
        bytes_hi = d["hlo"].get("mem_bytes", 0.0) / MEM_BYTES_CALIB
        bytes_lo = d["cost_analysis"]["bytes_accessed"]
        if not bytes_hi:
            bytes_hi = bytes_lo
    bytes_acc = (bytes_hi * bytes_lo) ** 0.5 if bytes_lo else bytes_hi
    coll = d["hlo"].get("collective_bytes_bf16norm",
                        d["hlo"]["total_collective_bytes"])
    t_c = flops / PEAK_FLOPS
    t_m = bytes_acc / HBM_BW
    t_n = coll / LINK_BW
    mf = model_flops(cfg, shape)
    mf_dev = mf / n_dev
    terms = {"compute": t_c, "memory": t_m, "collective": t_n}
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    frac = (mf_dev / PEAK_FLOPS) / bound if bound > 0 else 0.0
    return {
        "arch": arch, "shape": shape_name, "mesh": mesh,
        "compute_s": t_c, "memory_s": t_m, "collective_s": t_n,
        "dominant": dominant,
        "model_flops": mf, "hlo_flops_dev": flops,
        "useful_ratio": mf_dev / flops if flops else 0.0,
        "roofline_fraction": frac,
        "peak_mem_gib": d["memory"]["peak_bytes_est"] / 2**30,
        "fits_16g": d["memory"]["peak_bytes_est"] < 16 * 2**30,
        "accum": d.get("accum", 1),
        "compile_s": d.get("compile_s", 0.0),
        "memory_s_lo": bytes_lo / HBM_BW,
        "memory_s_hi": bytes_hi / HBM_BW,
    }


def full_table(mesh: str = "single"):
    from repro.configs import SHAPES, list_configs
    rows = []
    for arch in list_configs():
        for shape in SHAPES:
            r = cell_terms(arch, shape, mesh)
            if r:
                rows.append(r)
    return rows


def render_markdown(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful | roofline frac | peak GiB | fits |\n"
           "|---|---|---|---|---|---|---|---|---|---|\n")
    lines = []
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} | {r['peak_mem_gib']:.1f} | "
            f"{'y' if r['fits_16g'] else 'N'} |")
    return hdr + "\n".join(lines)
