"""Wall-clock-to-target-loss: every scheme as an epoch-assignment
policy over real gradients -- the training figure the paper implies.

The paper scores schemes by ``E[T_comp]`` for one batch of N units;
training asks the composed question: run S optimizer steps of N
microbatch gradients each, let the scheme decide which worker computes
which unit (and how leftovers move), and measure the virtual wall-clock
to a target loss.  Work conservation makes the per-step gradient sum --
and hence the entire loss curve -- bit-identical across policies
(pinned by ``validate`` and by ``tests/test_hettrain.py``), so the
schemes differ ONLY in how much wall-clock and straggler-wait they
spend buying the same optimization trajectory.

Three scenarios share one operating point (K=4, mu=4, sigma2=mu^2/6):
``stationary`` (rates pinned), ``drifting`` (AR(1) schedule moving the
true rates under every policy while schedulers see nominal ones --
except ``work_exchange_unknown``, whose online estimates follow), and
``trace`` (a measured-corpus window pacing the workers).

Like every figure driver, the study is declarative ``ExperimentSpec``s
through ``repro.experiments`` and the content-addressed store.
"""
from __future__ import annotations

from repro.experiments import (ExperimentResult, ExperimentSpec,
                               ScenarioGrid, run_experiment, scheme_spec)
from repro.hettrain import TrainConfig

# the epoch-assignment panel: exchange (known/unknown), static x2, coded
TRAIN_SCHEMES = ("work_exchange", "work_exchange_unknown", "uniform",
                 "fixed", "gradient_coded")
SCENARIOS = ("stationary", "drifting", "trace")

K_TRAIN = 4
MU = 4.0
SIGMA2 = MU * MU / 6.0
HET_SEED = 11
N_TRAIN = 16           # microbatch units per optimizer step
STEPS = 10
STEPS_QUICK = 4
TRIALS = 8
TARGET_LOSS = 3.2      # crossed mid-run at the full scale


def train_config(quick: bool = False) -> TrainConfig:
    return TrainConfig(steps=STEPS_QUICK if quick else STEPS,
                       target_loss=None if quick else TARGET_LOSS)


def _grid(scenario: str):
    point = (MU, SIGMA2, HET_SEED)
    if scenario == "stationary":
        return ScenarioGrid(K=K_TRAIN, points=[point])
    if scenario == "drifting":
        from repro.scenarios import DriftingScenario
        return DriftingScenario(K=K_TRAIN, points=(point,), kind="ar1",
                                rounds=64)
    if scenario == "trace":
        from repro.scenarios.traces import (DEFAULT_CORPUS,
                                            TraceCorpusScenario)
        return TraceCorpusScenario(corpus=DEFAULT_CORPUS, K=K_TRAIN,
                                   windows=((0, 0),), epochs=48)
    raise ValueError(f"unknown fig_train scenario {scenario!r}")


def experiment(trials: int = TRIALS, quick: bool = False,
               scenario: str = "stationary") -> ExperimentSpec:
    """The training study as a declarative spec, one per scenario."""
    tag = "-quick" if quick else ""
    return ExperimentSpec(
        name=f"fig-train-{scenario}{tag}",
        grid=_grid(scenario),
        schemes=tuple(scheme_spec(name) for name in TRAIN_SCHEMES),
        N=N_TRAIN, trials=(3 if quick else trials), seed=1234,
        training=train_config(quick))


def rows_from(result: ExperimentResult):
    """Flat row dicts, one per scheme: the figure's data table."""
    spec = result.spec
    scenario = {"drifting": "drifting",
                "trace_corpus": "trace"}.get(spec.grid.family,
                                             "stationary")
    rows = []
    for name in result.keys():
        for rep in result.report(name):
            tr = rep.extra["training"]
            rows.append({
                "scenario": scenario, "scheme": name,
                "mode": tr["mode"],
                "wall": rep.t_comp,            # mean virtual wall, all steps
                "epochs": rep.iterations,      # exchange epochs, all steps
                "n_comm": rep.n_comm,
                "loss_curve": tr["loss_curve"],
                "final_loss": tr["final_loss"],
                "wait_frac": tr["straggler_wait_frac"],
                "refetch_tokens": tr["refetch_tokens"],
                "steps_to_target": tr.get("steps_to_target"),
                "wall_to_target": tr.get("wall_to_target"),
                "nominal_rates_only":
                    bool(rep.extra.get("nominal_rates_only", 0)),
            })
    return rows


def run(trials: int = TRIALS, quick: bool = False, store=None,
        force: bool = False):
    rows = []
    scenarios = SCENARIOS[:2] if quick else SCENARIOS
    for scenario in scenarios:
        result = run_experiment(experiment(trials, quick, scenario),
                                store=store, force=force)
        rows += rows_from(result)
    return rows


def validate(rows, quick: bool = False) -> list:
    """The figure's claims as named boolean checks."""
    checks = []
    by = {}
    for r in rows:
        by.setdefault(r["scenario"], {})[r["scheme"]] = r
    steps = STEPS_QUICK if quick else STEPS
    for scen, schemes in sorted(by.items()):
        tag = f"fig_train[{scen}]"
        curves = {s: tuple(r["loss_curve"]) for s, r in schemes.items()}
        checks.append((f"{tag} loss curves bit-identical across all "
                       f"schemes", len(set(curves.values())) == 1))
        checks.append((f"{tag} positive wall-clock for every scheme",
                       all(r["wall"] > 0 for r in schemes.values())))
        we, un = schemes.get("work_exchange"), schemes.get("uniform")
        if we and un:
            checks.append((f"{tag} work_exchange wall < uniform wall",
                           we["wall"] < un["wall"]))
            checks.append((f"{tag} work_exchange waits less than uniform",
                           we["wait_frac"] < un["wait_frac"]))
        gc = schemes.get("gradient_coded")
        if gc:
            checks.append((f"{tag} gradient_coded: one epoch per step",
                           abs(gc["epochs"] - steps) < 1e-9))
    if quick:
        return checks
    stat = by.get("stationary", {})
    we, un = stat.get("work_exchange"), stat.get("uniform")
    if we and un and we.get("wall_to_target") and un.get("wall_to_target"):
        reached = (we["wall_to_target"] > 0 and un["wall_to_target"] > 0)
        checks.append(("fig_train[stationary] target loss reached within "
                       "the run", reached))
        if reached:
            checks.append(("fig_train[stationary] work_exchange reaches "
                           "target loss first",
                           we["wall_to_target"] < un["wall_to_target"]))
    return checks
