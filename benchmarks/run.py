"""Benchmark harness: one entry per paper figure + the roofline table.

Emits ``name,value,derived`` CSV rows and validates the paper's claims
against this reproduction (exit code reflects the validation).
Set REPRO_BENCH_QUICK=1 for a fast smoke pass.
"""
from __future__ import annotations

import os
import sys

QUICK = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))


def _emit(name: str, value, derived=""):
    print(f"{name},{value},{derived}")


def run_fig5():
    from . import fig5
    rows = fig5.run(quick=QUICK)
    for r in rows:
        tag = f"fig5[mu={r['mu']},s2={r['sigma2']}]"
        for scheme in ("oracle", "mds_opt", "fixed", "we_known",
                       "we_unknown"):
            _emit(f"{tag}.{scheme}_T_comp_s", f"{r[scheme]:.4f}",
                  f"L*={r['mds_L']}" if scheme == "mds_opt" else "")
    return fig5.validate(rows)


def run_fig6():
    from . import fig6
    rows = fig6.run(quick=QUICK)
    for r in rows:
        tag = f"fig6[s2={r['sigma2']:.0f}]"
        _emit(f"{tag}.comm_known_frac", f"{r['comm_known']:.5f}",
              f"std={r['comm_known_std']:.5f}")
        _emit(f"{tag}.comm_unknown_frac", f"{r['comm_unknown']:.5f}",
              f"std={r['comm_unknown_std']:.5f}")
        _emit(f"{tag}.iters_known", f"{r['iters_known']:.2f}")
        _emit(f"{tag}.iters_unknown", f"{r['iters_unknown']:.2f}")
    return fig6.validate(rows)


def run_fig7():
    from . import fig7
    rows = fig7.run(quick=QUICK)
    for r in rows:
        _emit(f"fig7[s2={r['sigma2']:.0f},th={r['threshold_frac']}].iters",
              f"{r['iters']:.2f}",
              f"T/oracle={r['t_comp_over_oracle']:.3f}")
    return fig7.validate(rows)


def run_roofline():
    from . import roofline
    try:
        rows = roofline.full_table("single")
    except Exception as e:  # dry-run results not present
        print(f"# roofline skipped: {e}", file=sys.stderr)
        return []
    for r in rows:
        _emit(f"roofline[{r['arch']},{r['shape']}].dominant_term_s",
              f"{max(r['compute_s'], r['memory_s'], r['collective_s']):.3e}",
              f"dom={r['dominant']};frac={r['roofline_fraction']:.3f}")
    return []


def main() -> None:
    checks = []
    checks += run_fig5()
    checks += run_fig6()
    checks += run_fig7()
    checks += run_roofline()
    failed = [name for name, ok in checks if not ok]
    print("#", "=" * 60)
    for name, ok in checks:
        print(f"# {'PASS' if ok else 'FAIL'}: {name}")
    print(f"# paper-claim checks: {len(checks) - len(failed)}/{len(checks)} "
          f"passed")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
