"""Benchmark harness: one entry per paper figure + the roofline table.

Emits ``name,value,derived`` CSV rows and validates the paper's claims
against this reproduction.  The figure studies run as declarative
``ExperimentSpec``s through ``repro.experiments``: each result lands in
the content-addressed store (``results/store/<spec-hash>.json``) and the
claim checks are validated against the report *read back from the
store*, so what the gate certifies is exactly what the store serves.

Also writes ``results/BENCH_schemes.json``: per-scheme mean T_comp
through the registry, wall-clock of the work-exchange MC engine
(per-trial loop vs vectorized), the fig5 scenario-grid benchmark (PR-1
per-point ``mc()`` loop vs one-dispatch ``mc_grid`` on the numpy / jax /
pallas sampler backends), the ``mds_grid`` benchmark (batched MDS
L-sweep vs the PR-2 per-L loop), the ``fig5_sharded`` benchmark
(single-device vs shard_map multi-device jax execution of the fig5 WE
grid), the ``panel`` section (fused whole-panel ``mc_grid_panel``
dispatch vs the per-scheme loop on the jax / pallas backends), the
``serve_load`` section (streaming-arrival engine wall +
per-policy p99 at a pinned load -- see ``benchmarks.fig_load``), the
``serve_scan`` section (the jitted ``lax.scan`` serving backend vs the
numpy slot loop over the full fig_load sweep, with the Erlang-C anchor
and the sharded-sweep drift), and the
``jax_cache`` section (cold vs warm first-call wall with the persistent
compilation cache), and the ``control_plane`` section (live async
execution: measured vs MC-predicted T_comp plus the coordination-wall
fraction -- see ``repro.control``), and the ``train`` section (the
batched ``lax.scan`` gradient engine vs the per-unit jitted loop it
replaced, plus the cross-policy bitwise-identity certificate -- see
``repro.hettrain``), so the perf trajectory is tracked
across PRs (see ``benchmarks.bench_gate``).

Set REPRO_BENCH_QUICK=1 for a fast smoke pass.  The sampler backend for
the figure sweeps follows REPRO_SAMPLER_BACKEND (default numpy).
REPRO_BENCH_DEVICES (default 4) forces that many simulated host devices
for the sharded benchmark when no real multi-device platform is
attached; REPRO_BENCH_CACHED=1 lets figure runs reuse store hits
instead of recomputing.

Exit codes distinguish the two failure modes:
  0 -- every paper-claim check passed
  1 -- benchmarks ran to completion but >= 1 validation check FAILED
  2 -- a benchmark CRASHED (traceback above the summary names it)
"""
from __future__ import annotations

import json
import os
import sys
import time
import traceback
from pathlib import Path

QUICK = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))
CACHED = bool(int(os.environ.get("REPRO_BENCH_CACHED", "0")))
BENCH_DEVICES = int(os.environ.get("REPRO_BENCH_DEVICES", "4"))

# simulated host devices for the sharded-grid benchmark: must be set
# before the first jax import anywhere in the process
if (BENCH_DEVICES > 1
        and "xla_force_host_platform_device_count"
        not in os.environ.get("XLA_FLAGS", "")):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={BENCH_DEVICES}").strip()

EXIT_VALIDATION_FAILED = 1
EXIT_CRASHED = 2


def _emit(name: str, value, derived=""):
    print(f"{name},{value},{derived}")


def _stored_result(mod, **kwargs):
    """Run a figure experiment through the store and hand back the rows
    REREAD from the stored entry -- claim validation is routed through
    the content-addressed record, not the in-memory run."""
    from repro.experiments import default_store, run_experiment

    store = default_store()
    spec = mod.experiment(quick=QUICK, **kwargs)
    result = run_experiment(spec, store=store, force=not CACHED)
    stored = store.get(result.spec_hash)
    _emit(f"{spec.name}.store", result.spec_hash[:16],
          "cache-hit" if result.cache_hit else "computed")
    return mod.rows_from(stored if stored is not None else result)


def run_fig5():
    from . import fig5
    rows = _stored_result(fig5)
    for r in rows:
        tag = f"fig5[mu={r['mu']},s2={r['sigma2']}]"
        for scheme in ("oracle", "mds_opt", "fixed", "we_known",
                       "we_unknown", "het_mds", "hedged"):
            if scheme not in r:      # panel member removed from FIG_SCHEMES
                continue
            _emit(f"{tag}.{scheme}_T_comp_s", f"{r[scheme]:.4f}",
                  f"L*={r['mds_L']}" if scheme == "mds_opt" else "")
    return fig5.validate(rows)


def run_fig6():
    from . import fig6
    rows = _stored_result(fig6)
    for r in rows:
        tag = f"fig6[s2={r['sigma2']:.0f}]"
        _emit(f"{tag}.comm_known_frac", f"{r['comm_known']:.5f}",
              f"std={r['comm_known_std']:.5f}")
        _emit(f"{tag}.comm_unknown_frac", f"{r['comm_unknown']:.5f}",
              f"std={r['comm_unknown_std']:.5f}")
        _emit(f"{tag}.iters_known", f"{r['iters_known']:.2f}")
        _emit(f"{tag}.iters_unknown", f"{r['iters_unknown']:.2f}")
    return fig6.validate(rows)


def run_fig7():
    from . import fig7
    rows = _stored_result(fig7)
    for r in rows:
        _emit(f"fig7[s2={r['sigma2']:.0f},th={r['threshold_frac']}].iters",
              f"{r['iters']:.2f}",
              f"T/oracle={r['t_comp_over_oracle']:.3f}")
    return fig7.validate(rows)


def run_fig_load():
    from . import fig_load
    rows = _stored_result(fig_load)
    rows += _stored_result(fig_load, scenario="drifting")
    for r in rows:
        tag = (f"fig_load[{r['scenario']},{r['scheme']},"
               f"load={r['load']:g}]")
        _emit(f"{tag}.sojourn_s", f"{r['sojourn']:.4f}",
              f"p99={r['p99']:.4f};thru={r['throughput_jobs']:.3f}/s;"
              f"slo_miss={r['slo_miss']:.3f}")
    for (scen, scheme), knee in sorted(fig_load.knees(rows).items()):
        _emit(f"fig_load[{scen},{scheme}].knee_load",
              "none" if knee is None else f"{knee:g}")
    return fig_load.validate(rows, quick=QUICK)


def run_fig_train():
    from . import fig_train
    rows = []
    scenarios = fig_train.SCENARIOS[:2] if QUICK else fig_train.SCENARIOS
    for scenario in scenarios:
        rows += _stored_result(fig_train, scenario=scenario)
    for r in rows:
        tag = f"fig_train[{r['scenario']},{r['scheme']}]"
        _emit(f"{tag}.wall_s", f"{r['wall']:.4f}",
              f"final_loss={r['final_loss']:.4f};"
              f"wait={r['wait_frac']:.3f};epochs={r['epochs']:.1f}")
        if r.get("wall_to_target") not in (None, -1.0):
            _emit(f"{tag}.wall_to_target_s", f"{r['wall_to_target']:.4f}",
                  f"steps={r['steps_to_target']}")
    return fig_train.validate(rows, quick=QUICK)


def _bench_fig5_grid(n: int, trials: int = 1000, reps: int = 5):
    """The tentpole measurement: fig5's (mu, sigma^2) scenario grid at
    trials=1000, PR-1 per-point ``mc()`` loop vs one-dispatch ``mc_grid``
    on every registered sampler backend (numpy / jax / pallas).

    The PR-1 baseline reproduces that code path faithfully, including its
    full-budget MDS L-sweep (PR 1 swept every candidate L at trials/2;
    the sweep is now bounded by ``opt_trials``).  Wall-clocks are
    min-over-reps (the standard noise-robust estimator); the first
    jax/pallas calls are recorded separately because they include jit
    compilation, which is paid once per batch-shape bucket and amortized
    across every later panel in the process.  On CPU runners the pallas
    backend times its bit-identical jnp reference path (the kernel needs
    a TPU to compile); it is recorded for trajectory, not as a CPU win.
    """
    if QUICK:               # smoke pass: keep the shape, shrink the budget
        trials, reps = 200, 1
    import numpy as np

    from repro.core.schemes import get_scheme
    from . import fig5
    from .common import FIG_SCHEMES

    specs = fig5.grid_specs(quick=QUICK)

    def pr1_loop():
        panel = {name: get_scheme(name) for name in FIG_SCHEMES}
        if "mds" in panel:     # PR 1 swept all K candidates at trials//2
            panel["mds"] = get_scheme("mds",
                                      opt_trials=max(8, trials // 2))
        rng = np.random.default_rng(1234)
        for het in specs:
            for name, scheme in panel.items():
                t = max(8, trials // 2) if name == "mds" else trials
                scheme.mc(het, n, trials=t, rng=rng, backend="numpy")

    def grid(backend):
        rng = np.random.default_rng(1234)
        for name in FIG_SCHEMES:
            get_scheme(name).mc_grid(specs, n, trials=trials, rng=rng,
                                     backend=backend)

    t0 = time.perf_counter()
    grid("jax")                                   # compiles the engine
    jax_first = time.perf_counter() - t0
    t0 = time.perf_counter()
    grid("pallas")                                # compiles the we_rounds path
    pallas_first = time.perf_counter() - t0
    # interleave the candidates so every path samples the same machine
    # phases (wall-clock on shared/bursty hosts drifts minute to minute),
    # then take the per-path min
    walls = {"loop": [], "numpy": [], "jax": [], "pallas": []}
    for _ in range(reps):
        for key, fn, args in (("loop", pr1_loop, ()),
                              ("numpy", grid, ("numpy",)),
                              ("jax", grid, ("jax",)),
                              ("pallas", grid, ("pallas",))):
            t0 = time.perf_counter()
            fn(*args)
            walls[key].append(time.perf_counter() - t0)
    loop_s = min(walls["loop"])
    numpy_grid_s = min(walls["numpy"])
    jax_s = min(walls["jax"])
    pallas_s = min(walls["pallas"])
    return {
        "N": n, "trials": trials, "grid_points": len(specs),
        "K": int(specs[0].K), "wall_reps": reps,
        "pr1_numpy_loop_s": round(loop_s, 4),
        "numpy_grid_s": round(numpy_grid_s, 4),
        "jax_grid_s": round(jax_s, 4),
        "jax_grid_first_call_s": round(jax_first, 4),
        "pallas_grid_s": round(pallas_s, 4),
        "pallas_grid_first_call_s": round(pallas_first, 4),
        "speedup_jax_vs_pr1_loop": round(loop_s / jax_s, 2),
        "speedup_jax_vs_pr1_loop_incl_compile": round(loop_s / jax_first, 2),
        "speedup_numpy_grid_vs_pr1_loop": round(loop_s / numpy_grid_s, 2),
        "speedup_pallas_vs_pr1_loop": round(loop_s / pallas_s, 2),
        "note": "full fig5 scheme panel over the (mu, sigma^2) grid; "
                "*_first_call_s includes one-off jit compilation (cached "
                "per batch-shape bucket within a process); pallas times "
                "its jnp reference path on hosts without TPU lowering",
    }


def _bench_mds_grid(n: int, trials: int = 1000, opt_trials: int = 500,
                    reps: int = 5):
    """The batched MDS L-sweep vs the PR-2 per-L Python loop at figure
    scale: every candidate L of every grid spec becomes extra rows of ONE
    ``gamma_rows`` dispatch (``MDSScheme.mc_grid``), instead of the
    K-iteration ``mds_sweep`` loop per spec.

    The PR-2 baseline reproduces the old ``mc`` path faithfully: the
    bounded per-L sweep loop, then the full-budget top-up draw for the
    winning L.  Identical draw budgets on both sides; the numpy grid is
    bit-identical to the loop (same stream), the jax/pallas grids swap
    the exact Gamma sampler for their batched transform samplers.
    """
    if QUICK:
        trials, opt_trials, reps = 200, 100, 1
    import numpy as np

    from repro.core.schemes import get_scheme, mds_sweep
    from . import fig5

    specs = fig5.grid_specs(quick=QUICK)

    def pr2_loop():
        rng = np.random.default_rng(77)
        for het in specs:
            sweep_trials = min(trials, opt_trials)
            L, _, _ = mds_sweep(het, n, sweep_trials, rng)
            if sweep_trials < trials:      # winner top-up, as PR-2 mc did
                m = int(np.ceil(n / L))
                t = rng.gamma(shape=m, scale=1.0 / het.lambdas,
                              size=(trials, het.K))
                t.sort(axis=1)

    def grid(backend):
        get_scheme("mds", opt_trials=opt_trials).mc_grid(
            specs, n, trials, np.random.default_rng(77), backend=backend)

    grid("jax")                          # pay jit compilation up front
    grid("pallas")
    walls = {"loop": [], "numpy": [], "jax": [], "pallas": []}
    for _ in range(reps):
        for key, fn, args in (("loop", pr2_loop, ()),
                              ("numpy", grid, ("numpy",)),
                              ("jax", grid, ("jax",)),
                              ("pallas", grid, ("pallas",))):
            t0 = time.perf_counter()
            fn(*args)
            walls[key].append(time.perf_counter() - t0)
    loop_s = min(walls["loop"])
    numpy_s = min(walls["numpy"])
    jax_s = min(walls["jax"])
    pallas_s = min(walls["pallas"])
    return {
        "N": n, "trials": trials, "opt_trials": opt_trials,
        "grid_points": len(specs), "K": int(specs[0].K),
        "wall_reps": reps,
        "pr2_loop_s": round(loop_s, 4),
        "numpy_grid_s": round(numpy_s, 4),
        "jax_grid_s": round(jax_s, 4),
        "pallas_grid_s": round(pallas_s, 4),
        "speedup_numpy_grid_vs_pr2_loop": round(loop_s / numpy_s, 2),
        "speedup_jax_grid_vs_pr2_loop": round(loop_s / jax_s, 2),
        "speedup_pallas_grid_vs_pr2_loop": round(loop_s / pallas_s, 2),
        "speedup_best_vs_pr2_loop": round(
            loop_s / min(numpy_s, jax_s, pallas_s), 2),
        "note": "all candidate L values of all specs in one gamma_rows "
                "dispatch vs the PR-2 per-spec per-L sweep loop, equal "
                "draw budgets; numpy grid is bit-identical to the loop",
    }


def _bench_fig5_sharded(n: int, trials: int = 1000, reps: int = 5):
    """The multi-device lever: fig5's work-exchange grid on the jax
    backend, single-device dispatch vs the shard_map executor
    (``repro.core.samplers.grid_sharding``) over the attached devices
    (simulated host devices on CPU runners -- see REPRO_BENCH_DEVICES).

    Times the two work-exchange schemes (the backend-routed, dominant
    cost of the panel); static/coded schemes draw host-side numpy
    regardless of backend and are unaffected by sharding.  Alongside the
    walls it records the statistical agreement between the two paths
    (max |mean drift| in combined standard errors over schemes x grid
    points) -- sharded runs use independent per-device key streams, so
    agreement is the 6-SE statistical contract, not bit-identity.
    """
    if QUICK:
        trials, reps = 200, 2
    import numpy as np

    from repro.core.samplers import grid_sharding
    from repro.core.schemes import get_scheme
    from . import fig5

    try:
        import jax
        devices = len(jax.devices())
    except Exception as e:      # pragma: no cover - jax always in CI
        return {"skipped": f"jax unavailable: {e}"}
    if devices < 2:
        return {"skipped": f"single-device host ({devices} device)"}

    specs = fig5.grid_specs(quick=QUICK)
    schemes = ("work_exchange", "work_exchange_unknown")

    def sweep(keep=False):
        out = {}
        for name in schemes:
            out[name] = get_scheme(name).mc_grid(
                specs, n, trials=trials, rng=np.random.default_rng(1234),
                backend="jax", keep_trials=keep)
        return out

    # warm both paths (jit compilation is cached per batch-shape bucket)
    single_reports = sweep(keep=True)
    with grid_sharding():
        sharded_reports = sweep(keep=True)
    drift_se = 0.0
    for name in schemes:
        for a, b in zip(single_reports[name], sharded_reports[name]):
            se = float(np.hypot(a.t_comp_std, b.t_comp_std)
                       / np.sqrt(trials))
            drift_se = max(drift_se, abs(a.t_comp - b.t_comp) / se)

    walls = {"single": [], "sharded": []}
    for _ in range(reps):
        t0 = time.perf_counter()
        sweep()
        walls["single"].append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        with grid_sharding():
            sweep()
        walls["sharded"].append(time.perf_counter() - t0)
    single_s = min(walls["single"])
    sharded_s = min(walls["sharded"])
    return {
        "N": n, "trials": trials, "grid_points": len(specs),
        "K": int(specs[0].K), "devices": devices, "wall_reps": reps,
        "schemes": list(schemes),
        "single_jax_s": round(single_s, 4),
        "sharded_jax_s": round(sharded_s, 4),
        "speedup_sharded_vs_single": round(single_s / sharded_s, 2),
        "max_mean_drift_se": round(drift_se, 2),
        "note": "fig5 work-exchange grid, jax backend: one-device "
                "dispatch vs shard_map over all attached devices "
                "(simulated host devices on CPU runners; per-device key "
                "streams, so agreement is statistical, not bitwise)",
    }


def _bench_fig5_drifting(n: int, trials: int = 1000, reps: int = 3):
    """The scenario-diversity lever: fig5's work-exchange panel under a
    drifting-heterogeneity grid (``repro.scenarios.DriftingScenario``),
    timed on every registered sampler backend.

    The per-round rate schedule changes the engines' inner-loop contract
    (one extra rate read per round), so this section both tracks the
    drift path's wall-clock and records the cross-backend agreement of
    the drifted means (max |mean - numpy mean| in combined standard
    errors over schemes x grid points): the numpy engine is the exact
    reference, jax/pallas run the fluid relaxation with the same
    schedule.
    """
    if QUICK:
        trials, reps = 200, 1
    import numpy as np

    from repro.core.schemes import get_scheme
    from . import fig5

    spec = fig5.drifting_experiment(quick=QUICK)
    fam = spec.grid
    specs, sched = fam.specs(), fam.rate_schedules()
    schemes = ("work_exchange", "work_exchange_unknown")

    def sweep(backend, keep=False):
        out = {}
        for name in schemes:
            out[name] = get_scheme(name).mc_grid(
                specs, n, trials=trials, rng=np.random.default_rng(1234),
                backend=backend, rate_schedule=sched, keep_trials=keep)
        return out

    # warm jit (compilation cached per batch-shape bucket) and collect
    # the agreement picture against the exact numpy engine
    reports = {b: sweep(b, keep=True) for b in ("numpy", "jax", "pallas")}
    drift_se = {}
    for backend in ("jax", "pallas"):
        worst = 0.0
        for name in schemes:
            for a, b in zip(reports["numpy"][name], reports[backend][name]):
                se = float(np.hypot(a.t_comp_std, b.t_comp_std)
                           / np.sqrt(trials))
                worst = max(worst, abs(a.t_comp - b.t_comp) / se)
        drift_se[backend] = round(worst, 2)

    walls = {"numpy": [], "jax": [], "pallas": []}
    for _ in range(reps):
        for key in walls:
            t0 = time.perf_counter()
            sweep(key)
            walls[key].append(time.perf_counter() - t0)
    numpy_s = min(walls["numpy"])
    jax_s = min(walls["jax"])
    pallas_s = min(walls["pallas"])
    return {
        "N": n, "trials": trials, "grid_points": len(specs),
        "K": int(specs[0].K), "rounds": int(sched.shape[1]),
        "kind": "ar1", "wall_reps": reps, "schemes": list(schemes),
        "numpy_grid_s": round(numpy_s, 4),
        "jax_grid_s": round(jax_s, 4),
        "pallas_grid_s": round(pallas_s, 4),
        "speedup_jax_vs_numpy": round(numpy_s / jax_s, 2),
        "max_mean_drift_se_jax": drift_se["jax"],
        "max_mean_drift_se_pallas": drift_se["pallas"],
        "note": "fig5 work-exchange panel under the drifting scenario "
                "family (AR(1) per-round rate schedule threaded through "
                "every backend); agreement is vs the exact numpy engine "
                "at MC tolerance",
    }


def _bench_panel(n: int, trials: int = 1000, reps: int = 3):
    """The fused whole-panel dispatch: fig5's work-exchange pair
    (known + unknown) through ONE ``mc_grid_panel`` call per backend --
    schemes x grid points in a single device dispatch -- vs the
    per-scheme ``mc_grid`` loop those schemes previously required.

    On jax the fused path couples the pair through one common-random-
    numbers engine (both trajectories share each round's bit stream), so
    the panel costs roughly one scheme instead of two; on pallas the
    known rows stack atop the unknown rows in one ``we_rounds_grid``
    launch.  The fused pair is *statistically* equivalent to per-scheme
    dispatch (recorded here in combined-SE units), not bitwise -- the
    executor keeps non-pair schemes bit-identical via its per-task rng
    mapping, which this benchmark does not exercise.
    """
    if QUICK:
        trials, reps = 200, 1
    import numpy as np

    from repro.core.schemes import get_scheme, mc_grid_panel
    from . import fig5

    specs = fig5.grid_specs(quick=QUICK)

    def make_schemes():
        return {"we_known": get_scheme("work_exchange"),
                "we_unknown": get_scheme("work_exchange_unknown")}

    def per_scheme(backend):
        out = {}
        for key, sch in make_schemes().items():
            out[key] = sch.mc_grid(specs, n, trials=trials,
                                   rng=np.random.default_rng(1234),
                                   backend=backend)
        return out

    def fused(backend):
        return mc_grid_panel(make_schemes(), specs, n, trials,
                             np.random.default_rng(1234), backend=backend)

    # warm the jit caches on both paths and collect the agreement
    # picture (fused vs per-scheme, same backend, in combined SEs)
    agree = {}
    for backend in ("jax", "pallas"):
        a, b = per_scheme(backend), fused(backend)
        worst = 0.0
        for key in a:
            for ra, rb in zip(a[key], b[key]):
                se = float(np.hypot(ra.t_comp_std, rb.t_comp_std)
                           / np.sqrt(trials))
                worst = max(worst,
                            abs(ra.t_comp - rb.t_comp) / max(se, 1e-12))
        agree[backend] = round(worst, 2)

    walls = {(m, b): [] for m in ("per_scheme", "fused")
             for b in ("jax", "pallas")}
    for _ in range(reps):
        for mode, fn in (("per_scheme", per_scheme), ("fused", fused)):
            for backend in ("jax", "pallas"):
                t0 = time.perf_counter()
                fn(backend)
                walls[(mode, backend)].append(time.perf_counter() - t0)
    out = {
        "N": n, "trials": trials, "grid_points": len(specs),
        "K": int(specs[0].K), "wall_reps": reps,
        "schemes": list(make_schemes()),
        "note": "fig5 work-exchange pair: one mc_grid_panel dispatch "
                "(fused) vs per-scheme mc_grid calls; jax fuses via a "
                "coupled common-random-numbers engine, pallas via a "
                "stacked we_rounds_grid launch; agreement is fused vs "
                "per-scheme in combined-SE units",
    }
    for backend in ("jax", "pallas"):
        per_s = min(walls[("per_scheme", backend)])
        fus_s = min(walls[("fused", backend)])
        out[f"per_scheme_{backend}_s"] = round(per_s, 4)
        out[f"fused_{backend}_s"] = round(fus_s, 4)
        out[f"speedup_{backend}"] = round(per_s / fus_s, 2)
        out[f"max_mean_drift_se_{backend}"] = agree[backend]
    return out


def _bench_serve_load(reps: int = 2):
    """The serving engine at the fig_load operating point: wall-clock of
    one load cell (the sweep's unit of work) plus per-scheme p99 sojourn
    at the pinned load, so dispatch-policy latency is tracked across PRs
    alongside the batch-mode T_comp means.
    """
    import dataclasses

    import numpy as np

    from repro.core.types import HetSpec
    from repro.serving import simulate_serving
    from . import fig_load

    het = HetSpec.uniform_random(fig_load.K_SERVE, fig_load.MU,
                                 fig_load.SIGMA2,
                                 np.random.default_rng(fig_load.HET_SEED))
    load = 0.85
    cfg = dataclasses.replace(fig_load.serving_config(quick=QUICK),
                              loads=(load,))
    trials = 4 if QUICK else fig_load.TRIALS

    wall = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        rep = simulate_serving(het, "work_exchange", {}, cfg,
                               fig_load.N_SERVE, load, trials,
                               np.random.default_rng(0))
        wall = min(wall, time.perf_counter() - t0)
    p99 = {"work_exchange": round(rep.extra["p99"], 4)}
    for name in fig_load.SERVE_SCHEMES:
        if name in p99:
            continue
        rep = simulate_serving(het, name, {}, cfg, fig_load.N_SERVE, load,
                               trials, np.random.default_rng(0))
        p99[name] = round(rep.extra["p99"], 4)
    return {
        "K": fig_load.K_SERVE, "N": fig_load.N_SERVE, "load": load,
        "slots": cfg.slots, "trials": trials, "wall_reps": reps,
        "deadline_slo": cfg.deadline_slo,
        "engine_wall_s": round(wall, 4),
        "p99_sojourn_s": p99,
        "note": "one fig_load cell (work_exchange, load 0.85) for the "
                "wall; p99 sojourn per dispatch policy at that load, "
                "fixed seeds",
    }


def _bench_serve_scan(reps: int = 2):
    """The jitted ``lax.scan`` serving engine vs the numpy slot loop at
    the full ``fig_load`` sweep scale.  The numpy wall is the historical
    per-(policy, load) Python loop; the jax wall is one warm dispatch
    per policy -- the whole load sweep rides the batch axis of a single
    ``lax.scan``, so the comparison is sweep-for-sweep.  Also recorded:
    the compile-inclusive first call, the max |numpy - jax| mean-sojourn
    drift in combined-SE units, an Erlang-C M/M/K closed-form anchor for
    the scan engine, and -- when simulated host devices are attached --
    the same sweep sharded over the device mesh with its drift vs the
    single-device run.
    """
    import numpy as np

    from repro.core.types import HetSpec
    from repro.serving import (ServingConfig, mmk_sojourn,
                               run_serving_grid, serving_backend_available)
    from . import fig_load

    if not serving_backend_available("jax"):
        return {"skipped": "jax serving backend unavailable"}

    trials = 4 if QUICK else fig_load.TRIALS
    if QUICK:
        reps = 1
    cfg = fig_load.serving_config(quick=QUICK)
    het = HetSpec.uniform_random(fig_load.K_SERVE, fig_load.MU,
                                 fig_load.SIGMA2,
                                 np.random.default_rng(fig_load.HET_SEED))

    def sweep(backend):
        return {name: run_serving_grid(name, {}, [het], cfg,
                                       fig_load.N_SERVE, trials, 1234,
                                       backend=backend)
                for name in fig_load.SERVE_SCHEMES}

    numpy_rows = sweep("numpy")
    t0 = time.perf_counter()
    jax_rows = sweep("jax")                      # compiles per policy
    first_call_s = time.perf_counter() - t0
    agree = 0.0
    for name in fig_load.SERVE_SCHEMES:
        for a, b in zip(numpy_rows[name], jax_rows[name]):
            se = max(float(np.hypot(a.t_comp_std, b.t_comp_std))
                     / float(np.sqrt(trials)), 1e-12)
            agree = max(agree, abs(a.t_comp - b.t_comp) / se)

    walls = {"numpy": float("inf"), "jax": float("inf")}
    for _ in range(reps):
        for key in walls:
            t0 = time.perf_counter()
            sweep(key)
            walls[key] = min(walls[key], time.perf_counter() - t0)

    # closed-form anchor: homogeneous workers + 1-unit jobs + pooled
    # work-exchange dispatch make the scan an M/M/K simulator up to
    # O(slot_dt) -- its mean sojourn must hit Erlang-C
    K_mmk, mu_mmk, load_mmk = 4, 20.0, 0.65
    mmk_cfg = ServingConfig(loads=(load_mmk,), slots=4000, slot_dt=0.0025,
                            warmup_frac=0.25)
    mmk_rep = run_serving_grid("work_exchange", {},
                               [HetSpec(np.full(K_mmk, mu_mmk))], mmk_cfg,
                               1, 16, 0, backend="jax")[0]
    mmk_expect = mmk_sojourn(load_mmk * K_mmk * mu_mmk, mu_mmk, K_mmk)
    mmk_rel = abs(mmk_rep.t_comp - mmk_expect) / mmk_expect

    out = {
        "K": fig_load.K_SERVE, "N": fig_load.N_SERVE,
        "loads": list(cfg.loads), "slots": cfg.slots, "trials": trials,
        "schemes": len(fig_load.SERVE_SCHEMES), "wall_reps": reps,
        "numpy_sweep_s": round(walls["numpy"], 4),
        "jax_sweep_s": round(walls["jax"], 4),
        "jax_first_call_s": round(first_call_s, 4),
        "speedup": round(walls["numpy"] / walls["jax"], 2),
        "max_mean_drift_se": round(agree, 2),
        "mmk_sojourn_expected_s": round(mmk_expect, 4),
        "mmk_sojourn_jax_s": round(mmk_rep.t_comp, 4),
        "mmk_rel_err": round(mmk_rel, 4),
        "note": "fig_load sweep, numpy slot loop vs one jitted lax.scan "
                "dispatch per policy (loads ride the batch axis); drift "
                "in combined-SE units; Erlang-C anchor at K=4 mu=20 "
                "load=0.65",
    }

    try:
        import jax
        devices = len(jax.devices())
    except Exception:                            # pragma: no cover
        devices = 1
    if devices > 1:
        from repro.core.samplers import grid_sharding
        with grid_sharding():
            sh_rows = sweep("jax")               # compiles sharded variant
            t0 = time.perf_counter()
            sweep("jax")
            sharded_s = time.perf_counter() - t0
        sh_agree = 0.0
        for name in fig_load.SERVE_SCHEMES:
            for a, b in zip(jax_rows[name], sh_rows[name]):
                se = max(float(np.hypot(a.t_comp_std, b.t_comp_std))
                         / float(np.sqrt(trials)), 1e-12)
                sh_agree = max(sh_agree, abs(a.t_comp - b.t_comp) / se)
        out["sharded_devices"] = devices
        out["sharded_jax_sweep_s"] = round(sharded_s, 4)
        out["max_sharded_drift_se"] = round(sh_agree, 2)
    return out


def _bench_jax_cache():
    """Cold vs warm first-call wall with the persistent jax compilation
    cache (``REPRO_JAX_CACHE_DIR``): two fresh subprocesses share one
    cache dir, so the second pays a disk read instead of XLA compilation.

    Each subprocess runs TWO different-shaped panels -- (K=12,
    trials=16) then (K=14, trials=24) -- that K/R shape bucketing pads
    to the same {rows: 64, K: 16} batch shape.  The second panel's wall
    inside the COLD process is therefore the bucketing win (one
    compilation serves both shapes, in-process); the warm process's
    first wall is the persistent-cache win (the shared bucket entry is
    read back from disk across processes).
    """
    import subprocess
    import tempfile

    prog = (
        "import time\n"
        "import numpy as np\n"
        "from repro.experiments.engine import "
        "_maybe_enable_jax_compilation_cache\n"
        "_maybe_enable_jax_compilation_cache()\n"
        "from repro.core.schemes import get_scheme\n"
        "from repro.core.types import HetSpec\n"
        "sch = get_scheme('work_exchange')\n"
        "for tag, K, trials in (('A', 12, 16), ('B', 14, 24)):\n"
        "    het = HetSpec.uniform_random(K, 20.0, 20.0 ** 2 / 6,"
        " np.random.default_rng(3))\n"
        "    t0 = time.perf_counter()\n"
        "    sch.mc_grid([het], 2000, trials=trials,"
        " rng=np.random.default_rng(0), backend='jax')\n"
        "    print(f'CALL_{tag} {time.perf_counter() - t0:.4f}')\n"
    )
    walls = {}
    with tempfile.TemporaryDirectory(prefix="repro-jax-cache-") as cache:
        for phase in ("cold", "warm"):
            env = dict(os.environ, REPRO_JAX_CACHE_DIR=cache)
            env.pop("REPRO_SHAPE_BUCKETS", None)   # bucketing must be on
            try:
                out = subprocess.run([sys.executable, "-c", prog],
                                     env=env, capture_output=True,
                                     text=True, timeout=300)
            except subprocess.TimeoutExpired:
                return {"skipped": f"{phase} subprocess timed out"}
            if out.returncode != 0:
                return {"skipped": f"{phase} subprocess failed: "
                                   f"{out.stderr.strip()[-300:]}"}
            for ln in out.stdout.splitlines():
                if ln.startswith("CALL_"):
                    tag, wall = ln.split()
                    walls[f"{phase}_{tag[5:]}"] = float(wall)
    cold, warm = walls["cold_A"], walls["warm_A"]
    return {
        "cold_first_call_s": round(cold, 4),
        "cold_second_shape_s": round(walls["cold_B"], 4),
        "warm_first_call_s": round(warm, 4),
        "warm_second_shape_s": round(walls["warm_B"], 4),
        "speedup_warm_vs_cold": round(cold / warm, 2),
        "speedup_bucket_vs_compile": round(cold / walls["cold_B"], 2),
        "note": "two different-shaped work_exchange jax panels "
                "(K=12/trials=16, then K=14/trials=24; both bucket to "
                "rows=64, K=16) per fresh process, REPRO_JAX_CACHE_DIR "
                "shared between the two runs: cold_second_shape shows "
                "in-process bucket reuse, warm_first shows the "
                "persistent cache serving the shared bucket entry",
    }


def _bench_control_plane(trials: int = 3):
    """The live async control plane at demo scale: ``trials`` executed
    work-exchange episodes (real transport round-trips, jitted matmul
    shards, Exp service clocks) against the MC prediction for the same
    operating point, plus the measured coordination-wall fraction --
    the paper's "limited coordination overhead" claim as a tracked
    number.
    """
    import numpy as np

    from repro.control import LiveConfig, run_live
    from repro.core.schemes import get_scheme
    from repro.core.types import HetSpec

    K, N, mu = 4, 2000, 4.0
    het = HetSpec.uniform_random(K, mu, mu ** 2 / 6,
                                 np.random.default_rng(7))
    if QUICK:
        trials = 2
    cfg = LiveConfig(target_wall_s=0.25 if QUICK else 0.5)
    mc_trials = 200 if QUICK else 1000
    try:
        rep = run_live("work_exchange", {}, het, N, cfg, trials, seed=11)
    except Exception as e:      # event loop / transport trouble on a
        return {"skipped": f"live episode failed: {e}"}     # CI runner
    mc = get_scheme("work_exchange").mc(het, N, trials=mc_trials,
                                        rng=np.random.default_rng(0))
    cp = rep.extra["control_plane"]
    se = float(np.hypot(rep.t_comp_std / np.sqrt(trials),
                        mc.t_comp_std / np.sqrt(mc_trials)))
    return {
        "K": K, "N": N, "trials": trials, "transport": cfg.transport,
        "payload_backend": cp["payload_backend"],
        "measured_t_comp": round(cp["measured_t_comp"], 4),
        "mc_predicted_t_comp": round(mc.t_comp, 4),
        "agreement_se": round(abs(rep.t_comp - mc.t_comp) / max(se, 1e-12),
                              2),
        "episode_wall_s": round(cp["episode_wall_s"], 4),
        "coordination_wall_s": round(cp["coordination_wall_s"], 4),
        "coordination_frac": round(cp["coordination_frac"], 4),
        "rpc_messages": cp["timeline"]["counters"].get("messages_sent", 0),
        "note": "live work_exchange episodes (inproc transport, jitted "
                "matmul shards) vs the MC prediction at the same "
                "operating point, fixed seeds; agreement in combined-SE "
                "units",
    }


def _bench_train(reps: int = 3):
    """The batched ``lax.scan`` gradient engine vs the per-unit jitted
    loop it replaced: one fused dispatch over a sorted, pow2-bucketed
    unit group against one ``value_and_grad`` device round trip per
    microbatch (the pre-refactor ``HetTrainer`` inner loop, reproduced
    faithfully: same jit, same f32 accumulation order).

    Alongside the walls, two correctness certificates ride along:
    the loop and the engine agree numerically on the gradient sum
    (same math, different fusion -- allclose, not bitwise), and three
    ``HetTrainer`` policies (static / exchange / coded) land
    BIT-identical final parameters from the same seed -- the work-
    conservation claim the whole training subsystem rests on.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.distributed.hetsched import HetTrainer
    from repro.hettrain import ScanGradEngine, TrainConfig

    n_units = 16 if QUICK else 64
    training = TrainConfig(steps=2)
    model, params = training.build_model()
    store = training.build_store()
    engine = ScanGradEngine(model, store)
    unit_ids = list(range(n_units))

    def unit_loss(p, batch):
        return model.loss(p, batch, mode="scan", remat=False)[0]

    per_unit = jax.jit(jax.value_and_grad(unit_loss))

    def loop():
        acc = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                           params)
        for u in unit_ids:
            _, g = per_unit(params, store.fetch(u))
            acc = jax.tree.map(
                lambda a, gg: a + gg.astype(jnp.float32), acc, g)
        jax.block_until_ready(acc)
        return acc

    def scan():
        g, _ = engine.grad_sum(params, unit_ids)
        jax.block_until_ready(g)
        return g

    loop_g = loop()                     # pay both compiles up front
    scan_g = scan()
    agree = all(np.allclose(a, b, rtol=2e-5, atol=1e-6)
                for a, b in zip(jax.tree.leaves(loop_g),
                                jax.tree.leaves(scan_g)))

    walls = {"loop": [], "scan": []}
    for _ in range(reps):
        for key, fn in (("loop", loop), ("scan", scan)):
            t0 = time.perf_counter()
            fn()
            walls[key].append(time.perf_counter() - t0)
    loop_s = min(walls["loop"])
    scan_s = min(walls["scan"])

    # bit-identity across policies: same seed, same unit stream, three
    # different schedulers -> np.array_equal final params
    rates = [1.0, 2.0, 4.0, 8.0]
    finals = []
    for policy in ("equal_static", "work_exchange", "gradient_coded"):
        trainer = HetTrainer(model, training.build_optimizer(), rates,
                             training.build_store(), policy=policy,
                             units_per_step=8, seed=3)
        p, _, _ = trainer.train(params, steps=2)
        finals.append(p)
    bitwise = all(
        all(np.array_equal(np.asarray(a), np.asarray(b))
            for a, b in zip(jax.tree.leaves(finals[0]),
                            jax.tree.leaves(f)))
        for f in finals[1:])

    return {
        "model": training.model, "units": n_units, "wall_reps": reps,
        "per_unit_loop_s": round(loop_s, 4),
        "scan_engine_s": round(scan_s, 4),
        "speedup_scan_vs_per_unit": round(loop_s / scan_s, 2),
        "grad_sum_allclose": bool(agree),
        "policies_bitwise_identical": bool(bitwise),
        "engine": engine.stats(),
        "note": "one optimizer step's gradient sum: per-unit jitted "
                "value_and_grad loop (the pre-refactor HetTrainer path) "
                "vs one bucketed lax.scan dispatch; bitwise certificate "
                "is final params across equal_static / work_exchange / "
                "gradient_coded at a fixed seed",
    }


def run_schemes_json(out_path: Path = Path("results/BENCH_schemes.json")):
    """Per-scheme MC means + engine/grid wall-clock, machine-readable."""
    import numpy as np

    from repro.core.schemes import get_scheme, list_schemes
    from .common import K_PAPER, N_PAPER, make_het, we_cfg

    n = 100_000 if QUICK else N_PAPER
    trials = 100 if QUICK else 1000
    het = make_het(50.0, 50.0 ** 2 / 6, seed=42)
    report = {"config": {"K": K_PAPER, "N": n, "mu": 50.0,
                         "sigma2": "mu^2/6", "trials": trials},
              "schemes": {}, "mc_engine": {}, "fig5_grid": {},
              "mds_grid": {}, "fig5_sharded": {}, "fig5_drifting": {},
              "panel": {}, "serve_load": {}, "serve_scan": {},
              "jax_cache": {}, "control_plane": {}, "train": {}}

    # per-trial-loop schemes walk unit ids in Python: bound their budget
    # (the JSON records the actual N/trials used -- no silent caps)
    loop_schemes = {"trace_replay", "gradient_coded"}
    for name in list_schemes():
        scheme = get_scheme(name)
        n_s = min(n, 20_000) if name in loop_schemes else n
        trials_s = min(trials, 20) if name in loop_schemes else trials
        wall = float("inf")
        for _ in range(2):      # min-of-reps: single-shot walls are noise
            t0 = time.perf_counter()
            rep = scheme.mc(het, n_s, trials=trials_s,
                            rng=np.random.default_rng(0))
            wall = min(wall, time.perf_counter() - t0)
        report["schemes"][name] = {
            "N": n_s, "trials": trials_s,
            "t_comp_mean": rep.t_comp, "t_comp_std": rep.t_comp_std,
            "iterations_mean": rep.iterations, "n_comm_mean": rep.n_comm,
            "wall_s": round(wall, 4),
        }

    # engine wall-clock: seed-style per-trial loop vs vectorized, same seed
    from repro.core.schemes import (simulate_work_exchange_scalar,
                                    work_exchange_mc_batched)
    cfg = we_cfg(known=False)
    loop_trials = max(10, trials // 10)
    t0 = time.perf_counter()
    rng = np.random.default_rng(0)
    for _ in range(loop_trials):
        simulate_work_exchange_scalar(het, n, cfg, rng)
    loop_s = (time.perf_counter() - t0) * (trials / loop_trials)
    vec_s = float("inf")
    for _ in range(2):
        t0 = time.perf_counter()
        work_exchange_mc_batched(het, n, cfg, trials,
                                 np.random.default_rng(0))
        vec_s = min(vec_s, time.perf_counter() - t0)
    report["mc_engine"] = {
        "loop_s_extrapolated": round(loop_s, 4),
        "loop_trials_measured": loop_trials,
        "vectorized_s": round(vec_s, 4),
        "speedup": round(loop_s / vec_s, 2),
        "note": "vectorized engine is RNG-bound (~80% of wall time is the "
                "exact Gamma/Binomial draws both paths make)",
    }

    report["fig5_grid"] = _bench_fig5_grid(n)
    report["mds_grid"] = _bench_mds_grid(n)
    report["fig5_sharded"] = _bench_fig5_sharded(n)
    report["fig5_drifting"] = _bench_fig5_drifting(n)
    report["panel"] = _bench_panel(n)
    report["serve_load"] = _bench_serve_load()
    report["serve_scan"] = _bench_serve_scan()
    report["jax_cache"] = _bench_jax_cache()
    report["control_plane"] = _bench_control_plane()
    report["train"] = _bench_train()

    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(report, indent=2))
    g = report["fig5_grid"]
    m = report["mds_grid"]
    s = report["fig5_sharded"]
    d = report["fig5_drifting"]
    shard_note = (f"sharded {s['speedup_sharded_vs_single']}x on "
                  f"{s['devices']} devices"
                  if "speedup_sharded_vs_single" in s
                  else f"sharded: {s.get('skipped', 'n/a')}")
    p = report["panel"]
    sv = report["serve_load"]
    sc = report["serve_scan"]
    scan_note = (f"serve scan {sc['speedup']}x vs numpy sweep, "
                 f"drift <= {sc['max_mean_drift_se']} SE"
                 if "speedup" in sc
                 else f"serve scan: {sc.get('skipped', 'n/a')}")
    jc = report["jax_cache"]
    cache_note = (f"jax cache warm {jc['speedup_warm_vs_cold']}x vs cold"
                  if "speedup_warm_vs_cold" in jc
                  else f"jax cache: {jc.get('skipped', 'n/a')}")
    ctl = report["control_plane"]
    ctl_note = (f"live vs MC {ctl['agreement_se']} SE, coord "
                f"{100 * ctl['coordination_frac']:.1f}%"
                if "agreement_se" in ctl
                else f"live: {ctl.get('skipped', 'n/a')}")
    tr = report["train"]
    train_note = (f"train scan {tr['speedup_scan_vs_per_unit']}x vs "
                  f"per-unit loop, policies bitwise="
                  f"{tr['policies_bitwise_identical']}"
                  if "speedup_scan_vs_per_unit" in tr
                  else f"train: {tr.get('skipped', 'n/a')}")
    print(f"# wrote {out_path} (engine speedup "
          f"{report['mc_engine']['speedup']}x; fig5 grid: jax "
          f"{g['speedup_jax_vs_pr1_loop']}x vs PR1 loop, "
          f"{g['speedup_jax_vs_pr1_loop_incl_compile']}x incl compile, "
          f"pallas {g['speedup_pallas_vs_pr1_loop']}x; mds grid: best "
          f"{m['speedup_best_vs_pr2_loop']}x vs PR2 loop; {shard_note}; "
          f"drifting: jax {d['speedup_jax_vs_numpy']}x vs numpy, "
          f"agreement <= {max(d['max_mean_drift_se_jax'], d['max_mean_drift_se_pallas'])} SE; "
          f"fused panel {p['speedup_jax']}x on jax; "
          f"serve cell {sv['engine_wall_s']}s; {scan_note}; {cache_note}; "
          f"{ctl_note}; {train_note})",
          file=sys.stderr)
    checks = []
    if "speedup" in sc:
        # the quick config is too small to amortize dispatch, so the
        # speedup bar is only meaningful at the full fig_load scale
        if not QUICK:
            checks.append(("serve_scan: jax scan >= 3x the numpy sweep",
                           sc["speedup"] >= 3.0))
        checks.append(("serve_scan: numpy-vs-jax drift within 6 SE",
                       sc["max_mean_drift_se"] <= 6.0))
        checks.append(("serve_scan: Erlang-C M/M/K anchor within 15%",
                       sc["mmk_rel_err"] <= 0.15))
        if "max_sharded_drift_se" in sc:
            checks.append(("serve_scan: sharded within 6 SE of "
                           "single-device", sc["max_sharded_drift_se"] <= 6.0))
    return checks


def run_roofline():
    from . import roofline
    try:
        rows = roofline.full_table("single")
    except Exception as e:  # dry-run results not present
        print(f"# roofline skipped: {e}", file=sys.stderr)
        return []
    for r in rows:
        _emit(f"roofline[{r['arch']},{r['shape']}].dominant_term_s",
              f"{max(r['compute_s'], r['memory_s'], r['collective_s']):.3e}",
              f"dom={r['dominant']};frac={r['roofline_fraction']:.3f}")
    return []


def main() -> None:
    checks = []
    crashed = []
    for step in (run_fig5, run_fig6, run_fig7, run_fig_load,
                 run_fig_train, run_schemes_json, run_roofline):
        try:
            checks += step()
        except Exception:
            traceback.print_exc()
            crashed.append(step.__name__)
            print(f"# CRASH: {step.__name__} raised "
                  f"{sys.exc_info()[0].__name__} (traceback above)",
                  file=sys.stderr)
    failed = [name for name, ok in checks if not ok]
    print("#", "=" * 60)
    for name, ok in checks:
        print(f"# {'PASS' if ok else 'FAIL'}: {name}")
    print(f"# paper-claim checks: {len(checks) - len(failed)}/{len(checks)} "
          f"passed")
    if crashed:
        print(f"# CRASHED benchmarks: {', '.join(crashed)} -> exit "
              f"{EXIT_CRASHED}")
        sys.exit(EXIT_CRASHED)
    if failed:
        print(f"# validation failures -> exit {EXIT_VALIDATION_FAILED}")
        sys.exit(EXIT_VALIDATION_FAILED)


if __name__ == "__main__":
    main()
