"""Benchmark harness: one entry per paper figure + the roofline table.

Emits ``name,value,derived`` CSV rows and validates the paper's claims
against this reproduction (exit code reflects the validation).  Also
writes ``results/BENCH_schemes.json``: per-scheme mean T_comp through the
registry plus wall-clock of the work-exchange MC engine (per-trial loop
vs vectorized), so the perf trajectory is tracked across PRs.
Set REPRO_BENCH_QUICK=1 for a fast smoke pass.
"""
from __future__ import annotations

import json
import os
import sys
import time
from pathlib import Path

QUICK = bool(int(os.environ.get("REPRO_BENCH_QUICK", "0")))


def _emit(name: str, value, derived=""):
    print(f"{name},{value},{derived}")


def run_fig5():
    from . import fig5
    rows = fig5.run(quick=QUICK)
    for r in rows:
        tag = f"fig5[mu={r['mu']},s2={r['sigma2']}]"
        for scheme in ("oracle", "mds_opt", "fixed", "we_known",
                       "we_unknown", "het_mds"):
            if scheme not in r:      # panel member removed from FIG_SCHEMES
                continue
            _emit(f"{tag}.{scheme}_T_comp_s", f"{r[scheme]:.4f}",
                  f"L*={r['mds_L']}" if scheme == "mds_opt" else "")
    return fig5.validate(rows)


def run_fig6():
    from . import fig6
    rows = fig6.run(quick=QUICK)
    for r in rows:
        tag = f"fig6[s2={r['sigma2']:.0f}]"
        _emit(f"{tag}.comm_known_frac", f"{r['comm_known']:.5f}",
              f"std={r['comm_known_std']:.5f}")
        _emit(f"{tag}.comm_unknown_frac", f"{r['comm_unknown']:.5f}",
              f"std={r['comm_unknown_std']:.5f}")
        _emit(f"{tag}.iters_known", f"{r['iters_known']:.2f}")
        _emit(f"{tag}.iters_unknown", f"{r['iters_unknown']:.2f}")
    return fig6.validate(rows)


def run_fig7():
    from . import fig7
    rows = fig7.run(quick=QUICK)
    for r in rows:
        _emit(f"fig7[s2={r['sigma2']:.0f},th={r['threshold_frac']}].iters",
              f"{r['iters']:.2f}",
              f"T/oracle={r['t_comp_over_oracle']:.3f}")
    return fig7.validate(rows)


def run_schemes_json(out_path: Path = Path("results/BENCH_schemes.json")):
    """Per-scheme MC means + engine wall-clock, machine-readable."""
    import numpy as np

    from repro.core.schemes import get_scheme, list_schemes
    from .common import K_PAPER, N_PAPER, make_het, we_cfg

    n = 100_000 if QUICK else N_PAPER
    trials = 100 if QUICK else 1000
    het = make_het(50.0, 50.0 ** 2 / 6, seed=42)
    report = {"config": {"K": K_PAPER, "N": n, "mu": 50.0,
                         "sigma2": "mu^2/6", "trials": trials},
              "schemes": {}, "mc_engine": {}}

    # per-trial-loop schemes walk unit ids in Python: bound their budget
    # (the JSON records the actual N/trials used -- no silent caps)
    loop_schemes = {"trace_replay", "gradient_coded"}
    for name in list_schemes():
        scheme = get_scheme(name)
        n_s = min(n, 20_000) if name in loop_schemes else n
        trials_s = min(trials, 20) if name in loop_schemes else trials
        if name == "mds":            # bounds the inner L-sweep (K x trials)
            trials_s = min(trials, 200)
        t0 = time.perf_counter()
        rep = scheme.mc(het, n_s, trials=trials_s,
                        rng=np.random.default_rng(0))
        report["schemes"][name] = {
            "N": n_s, "trials": trials_s,
            "t_comp_mean": rep.t_comp, "t_comp_std": rep.t_comp_std,
            "iterations_mean": rep.iterations, "n_comm_mean": rep.n_comm,
            "wall_s": round(time.perf_counter() - t0, 4),
        }

    # engine wall-clock: seed-style per-trial loop vs vectorized, same seed
    from repro.core.schemes import (simulate_work_exchange_scalar,
                                    work_exchange_mc_batched)
    cfg = we_cfg(known=False)
    loop_trials = max(10, trials // 10)
    t0 = time.perf_counter()
    rng = np.random.default_rng(0)
    for _ in range(loop_trials):
        simulate_work_exchange_scalar(het, n, cfg, rng)
    loop_s = (time.perf_counter() - t0) * (trials / loop_trials)
    t0 = time.perf_counter()
    work_exchange_mc_batched(het, n, cfg, trials, np.random.default_rng(0))
    vec_s = time.perf_counter() - t0
    report["mc_engine"] = {
        "loop_s_extrapolated": round(loop_s, 4),
        "loop_trials_measured": loop_trials,
        "vectorized_s": round(vec_s, 4),
        "speedup": round(loop_s / vec_s, 2),
        "note": "vectorized engine is RNG-bound (~80% of wall time is the "
                "exact Gamma/Binomial draws both paths make)",
    }

    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(report, indent=2))
    print(f"# wrote {out_path} (engine speedup "
          f"{report['mc_engine']['speedup']}x)", file=sys.stderr)
    return []


def run_roofline():
    from . import roofline
    try:
        rows = roofline.full_table("single")
    except Exception as e:  # dry-run results not present
        print(f"# roofline skipped: {e}", file=sys.stderr)
        return []
    for r in rows:
        _emit(f"roofline[{r['arch']},{r['shape']}].dominant_term_s",
              f"{max(r['compute_s'], r['memory_s'], r['collective_s']):.3e}",
              f"dom={r['dominant']};frac={r['roofline_fraction']:.3f}")
    return []


def main() -> None:
    checks = []
    checks += run_fig5()
    checks += run_fig6()
    checks += run_fig7()
    checks += run_schemes_json()
    checks += run_roofline()
    failed = [name for name, ok in checks if not ok]
    print("#", "=" * 60)
    for name, ok in checks:
        print(f"# {'PASS' if ok else 'FAIL'}: {name}")
    print(f"# paper-claim checks: {len(checks) - len(failed)}/{len(checks)} "
          f"passed")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
