"""Occupancy timeline: per-worker busy/idle spans from a live episode.

The live control plane (``repro.control``) stamps every worker with
telemetry spans -- busy computing a round vs. idle awaiting assignment
-- and ships them in ``MCReport.extra["control_plane"]["timeline"]``.
This figure renders that record as an ASCII per-worker timeline: one
row per worker, ``#`` for busy wall-time, ``.`` for idle, with the
per-worker busy fraction and units completed in the margin.  It is the
visual form of the paper's straggler story: under static assignment the
fast workers' rows go idle-dotted while the slow worker's row stays
solid; under work exchange every row stays mostly solid.

Two entry points:

* ``render_timeline(control_plane)`` -- pure function from the stored
  ``extra["control_plane"]`` dict (or a bare ``Telemetry.to_dict()``)
  to the ASCII figure; falls back to occupancy-summary bars for store
  entries written before raw spans were exported.
* CLI -- render from the content-addressed store (``--hash`` or every
  entry carrying control-plane telemetry), or ``--live`` to run one
  quick in-process episode and render it immediately::

      PYTHONPATH=src python -m benchmarks.fig_timeline --live
      PYTHONPATH=src python -m benchmarks.fig_timeline --hash <spec-hash>
"""
from __future__ import annotations

import argparse
import sys
from typing import Any, Dict, List

DEFAULT_WIDTH = 64
_GLYPH = {"busy": "#", "idle": "."}


def _timeline_of(control: Dict[str, Any]) -> Dict[str, Any]:
    """Accept either ``extra["control_plane"]`` or a bare timeline."""
    if "timeline" in control:
        return control["timeline"]
    return control


def _span_rows(spans: Dict[str, List[dict]], width: int) -> List[str]:
    t_max = max((s["t1"] for ss in spans.values() for s in ss),
                default=0.0)
    if t_max <= 0:
        return []
    rows = []
    for worker in sorted(spans, key=lambda w: int(w)):
        cells = [" "] * width
        busy = units = 0.0
        for s in spans[worker]:
            glyph = _GLYPH.get(s.get("state"), "?")
            lo = int(s["t0"] / t_max * width)
            hi = max(lo + 1, int(s["t1"] / t_max * width))
            for i in range(lo, min(hi, width)):
                # busy wins ties on shared cells: a sliver of work in a
                # mostly-idle cell still reads as activity
                if cells[i] != _GLYPH["busy"]:
                    cells[i] = glyph
            if s.get("state") == "busy":
                busy += s["t1"] - s["t0"]
                units += s.get("units", 0)
        frac = busy / t_max
        rows.append(f"  w{int(worker):<3d} |{''.join(cells)}| "
                    f"busy {100 * frac:5.1f}%  units {int(units)}")
    rows.append(f"  {'':>5} +{'-' * width}+  span 0 .. {t_max:.3f}s")
    return rows


def _occupancy_rows(occ: Dict[str, dict], width: int) -> List[str]:
    """Fallback for records predating raw span export: summary bars."""
    rows = []
    for worker in sorted(occ, key=lambda w: int(w)):
        o = occ[worker]
        total = o["busy_s"] + o["idle_s"]
        n_busy = int(round(width * o["busy_s"] / total)) if total > 0 else 0
        bar = _GLYPH["busy"] * n_busy + _GLYPH["idle"] * (width - n_busy)
        frac = o["busy_s"] / total if total > 0 else 0.0
        rows.append(f"  w{int(worker):<3d} |{bar}| "
                    f"busy {100 * frac:5.1f}%  units {o['units_done']}")
    return rows


def render_timeline(control: Dict[str, Any],
                    width: int = DEFAULT_WIDTH) -> str:
    """ASCII per-worker busy/idle timeline from control-plane telemetry.

    Prefers the raw ``spans`` (true time-resolved rows); degrades to
    occupancy-summary bars when only aggregates were stored.
    """
    tl = _timeline_of(control)
    spans = tl.get("spans") or {}
    rows = _span_rows(spans, width) if spans else []
    mode = "spans"
    if not rows:
        rows = _occupancy_rows(tl.get("occupancy") or {}, width)
        mode = "occupancy summary"
    if not rows:
        return "  (no worker telemetry recorded)"
    head = [f"  worker timeline ({mode}; '#' busy, '.' idle)"]
    counters = tl.get("counters") or {}
    tail = []
    if counters:
        tail.append("  " + "  ".join(
            f"{k}={v}" for k, v in sorted(counters.items())
            if k in ("units_dispatched", "units_completed",
                     "units_reassigned", "rpc_retries")))
    return "\n".join(head + rows + tail)


def render_report(rep, width: int = DEFAULT_WIDTH) -> str:
    """Timeline plus the episode headline for one live MCReport."""
    control = rep.extra["control_plane"]
    head = (f"scheme={rep.scheme}  T_comp={rep.t_comp:.3f} "
            f"(model {control.get('expected_model_s', float('nan')):.3f})"
            f"  transport={control.get('transport', '?')}")
    return head + "\n" + render_timeline(control, width)


def _live_reports(scheme: str, transport: str):
    """One quick in-process live episode per scheme for --live mode."""
    import numpy as np

    from repro.control import LiveConfig, run_live
    from repro.core.types import HetSpec

    het = HetSpec.uniform_random(K=4, mu=4.0, sigma2=4.0 ** 2 / 6,
                                 rng=np.random.default_rng(11))
    cfg = LiveConfig(transport=transport, target_wall_s=0.3)
    schemes = ([scheme] if scheme
               else ["fixed", "work_exchange"])
    return [run_live(name, {}, het, N=64, cfg=cfg, trials=1, seed=5)
            for name in schemes]


def _store_reports(store_root: str, spec_hash: str):
    from repro.experiments import ResultsStore

    store = ResultsStore(store_root)
    hashes = [spec_hash] if spec_hash else store.entries()
    out = []
    for h in hashes:
        result = store.get(h)
        if result is None:
            continue
        for key in result.keys():
            for rep in result.report(key):
                if "control_plane" in rep.extra:
                    out.append(rep)
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--store", default="results/store",
                    help="content-addressed store root to scan")
    ap.add_argument("--hash", default=None,
                    help="render one store entry by spec hash")
    ap.add_argument("--live", action="store_true",
                    help="run one quick live episode and render it")
    ap.add_argument("--scheme", default=None,
                    help="with --live: a single scheme (default: fixed "
                         "and work_exchange side by side)")
    ap.add_argument("--transport", default="inproc",
                    help="with --live: transport name (inproc, tcp, ...)")
    ap.add_argument("--width", type=int, default=DEFAULT_WIDTH)
    args = ap.parse_args(argv)

    reports = (_live_reports(args.scheme, args.transport) if args.live
               else _store_reports(args.store, args.hash))
    if not reports:
        print("no control-plane telemetry found (run a live episode: "
              "--live, or `python -m repro.experiments --demo live`)",
              file=sys.stderr)
        return 1
    for rep in reports:
        print(render_report(rep, args.width))
        print()
    return 0


if __name__ == "__main__":
    sys.exit(main())
