"""Paper Figure 7: reassignment iterations I versus the cutting threshold
N_rem^th for the unknown-heterogeneity work exchange (mu = 50), and the
companion claim that T_comp stays near-oracle at the default threshold.

The threshold is a Scheme constructor parameter, so the sweep is one
``mc_grid`` dispatch over the sigma^2 axis per threshold value."""
from __future__ import annotations

import numpy as np

from repro.core.schemes import get_scheme
from .common import N_PAPER, make_het

MU = 50.0
SIGMA2S = (0.0, 277.0, 833.0)
# thresholds as fractions of N/K (paper default 0.01)
THRESH_FRACS = (0.001, 0.005, 0.01, 0.05, 0.2, 0.5)


def run(n: int = N_PAPER, trials: int = 8, quick: bool = False,
        backend: str | None = None):
    rows = []
    fracs = THRESH_FRACS[::2] if quick else THRESH_FRACS
    sigma2s = SIGMA2S[::2] if quick else SIGMA2S
    specs = [make_het(MU, sigma2, seed=int(sigma2) + 7) for sigma2 in sigma2s]
    oracle_ts = [n / het.lambda_sum for het in specs]
    for frac in fracs:
        scheme = get_scheme("work_exchange_unknown", threshold_frac=frac)
        reports = scheme.mc_grid(specs, n, trials=trials,
                                 rng=np.random.default_rng(int(frac * 1e6)),
                                 backend=backend)
        for sigma2, oracle_t, rep in zip(sigma2s, oracle_ts, reports):
            rows.append({"sigma2": sigma2, "threshold_frac": frac,
                         "iters": rep.iterations,
                         "t_comp_over_oracle": rep.t_comp / oracle_t})
    return rows


def validate(rows) -> list[str]:
    checks = []
    by_sigma = {}
    for r in rows:
        by_sigma.setdefault(r["sigma2"], []).append(r)
    for sigma2, rs in by_sigma.items():
        rs = sorted(rs, key=lambda r: r["threshold_frac"])
        checks.append((f"fig7[s2={sigma2}] I non-increasing in threshold",
                       all(rs[i]["iters"] >= rs[i + 1]["iters"] - 0.5
                           for i in range(len(rs) - 1))))
    # default threshold keeps T_comp near oracle (paper Sec. 7)
    default = [r for r in rows if r["threshold_frac"] == 0.01]
    if default:
        checks.append(("fig7 default threshold keeps T within 10% of oracle",
                       all(r["t_comp_over_oracle"] < 1.10 for r in default)))
    return checks
