"""Paper Figure 7: reassignment iterations I versus the cutting threshold
N_rem^th for the unknown-heterogeneity work exchange (mu = 50), and the
companion claim that T_comp stays near-oracle at the default threshold.

The threshold is a scheme constructor parameter, so the sweep is one
declarative ``ExperimentSpec`` with one task per threshold value (each
with its historical per-threshold seed, keeping the numpy numbers
seed-for-seed bit-identical to the pre-spec driver) over the sigma^2
scenario grid."""
from __future__ import annotations

from repro.experiments import (ExperimentResult, ExperimentSpec,
                               ScenarioGrid, run_experiment, scheme_spec)
from .common import K_PAPER, N_PAPER

MU = 50.0
SIGMA2S = (0.0, 277.0, 833.0)
# thresholds as fractions of N/K (paper default 0.01)
THRESH_FRACS = (0.001, 0.005, 0.01, 0.05, 0.2, 0.5)


def experiment(n: int = N_PAPER, trials: int = 8, quick: bool = False,
               backend: str | None = None) -> ExperimentSpec:
    fracs = THRESH_FRACS[::2] if quick else THRESH_FRACS
    sigma2s = SIGMA2S[::2] if quick else SIGMA2S
    points = [(MU, sigma2, int(sigma2) + 7) for sigma2 in sigma2s]
    return ExperimentSpec(
        name="fig7-quick" if quick else "fig7",
        grid=ScenarioGrid(K=K_PAPER, points=points),
        schemes=tuple(scheme_spec("work_exchange_unknown",
                                  key=f"th={frac}", threshold_frac=frac,
                                  seed=int(frac * 1e6))
                      for frac in fracs),
        N=n, trials=trials, backend=backend)


def rows_from(result: ExperimentResult):
    n = result.spec.N
    hets = result.spec.grid.specs()
    sigma2s = [s2 for _, s2, _ in result.spec.grid.points]
    oracle_ts = [n / het.lambda_sum for het in hets]
    rows = []
    for key in result.keys():
        frac = float(key.split("=", 1)[1])
        for sigma2, oracle_t, rep in zip(sigma2s, oracle_ts,
                                         result.report(key)):
            rows.append({"sigma2": sigma2, "threshold_frac": frac,
                         "iters": rep.iterations,
                         "t_comp_over_oracle": rep.t_comp / oracle_t})
    return rows


def run(n: int = N_PAPER, trials: int = 8, quick: bool = False,
        backend: str | None = None, store=None, force: bool = False):
    result = run_experiment(experiment(n, trials, quick, backend),
                            store=store, force=force)
    return rows_from(result)


def validate(rows) -> list[str]:
    checks = []
    by_sigma = {}
    for r in rows:
        by_sigma.setdefault(r["sigma2"], []).append(r)
    for sigma2, rs in by_sigma.items():
        rs = sorted(rs, key=lambda r: r["threshold_frac"])
        checks.append((f"fig7[s2={sigma2}] I non-increasing in threshold",
                       all(rs[i]["iters"] >= rs[i + 1]["iters"] - 0.5
                           for i in range(len(rs) - 1))))
    # default threshold keeps T_comp near oracle (paper Sec. 7)
    default = [r for r in rows if r["threshold_frac"] == 0.01]
    if default:
        checks.append(("fig7 default threshold keeps T within 10% of oracle",
                       all(r["t_comp_over_oracle"] < 1.10 for r in default)))
    return checks
