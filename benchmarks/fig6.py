"""Paper Figures 6a/6b: extra communication N_comm/N and reassignment
iterations I versus heterogeneity variance sigma^2, for work exchange
with and without heterogeneity knowledge (mu = 50, K = 50, N = 1e6).

The whole (sigma^2 x heterogeneity-draw) scenario grid runs through one
``mc_grid`` dispatch per variant; the sampler backend follows
``REPRO_SAMPLER_BACKEND`` / the ``backend=`` argument."""
from __future__ import annotations

import numpy as np

from repro.core.schemes import get_scheme
from .common import HET_DRAWS, N_PAPER, THRESHOLD_FRAC, make_het

MU = 50.0
SIGMA2S = (0.0, 166.0, 333.0, 500.0, 666.0, 833.0)   # up to mu^2/3

VARIANTS = (("known", "work_exchange"), ("unknown", "work_exchange_unknown"))


def run(n: int = N_PAPER, draws: int = HET_DRAWS, trials: int = 4,
        quick: bool = False, backend: str | None = None):
    sigma2s = SIGMA2S[::2] if quick else SIGMA2S
    n_draws = max(4, draws // 4) if quick else draws
    # the full grid is (sigma^2 x draw): one spec per cell, grid-major
    specs = [make_het(MU, sigma2, seed=1000 + d)
             for sigma2 in sigma2s for d in range(n_draws)]
    per_variant = {}
    for label, name in VARIANTS:
        scheme = get_scheme(name, threshold_frac=THRESHOLD_FRAC)
        per_variant[label] = scheme.mc_grid(
            specs, n, trials=trials, rng=np.random.default_rng(2024),
            backend=backend)
    rows = []
    for i, sigma2 in enumerate(sigma2s):
        cell = slice(i * n_draws, (i + 1) * n_draws)
        comm = {lbl: np.array([r.n_comm / n for r in reps[cell]])
                for lbl, reps in per_variant.items()}
        iters = {lbl: np.array([r.iterations for r in reps[cell]])
                 for lbl, reps in per_variant.items()}
        rows.append({
            "sigma2": sigma2,
            "comm_known": float(comm["known"].mean()),
            "comm_known_std": float(comm["known"].std()),
            "comm_unknown": float(comm["unknown"].mean()),
            "comm_unknown_std": float(comm["unknown"].std()),
            "iters_known": float(iters["known"].mean()),
            "iters_unknown": float(iters["unknown"].mean()),
        })
    return rows


def validate(rows) -> list[str]:
    checks = []
    first, last = rows[0], rows[-1]
    checks.append(("fig6a known-het comm ~ 0 at every sigma^2",
                   all(r["comm_known"] < 0.02 for r in rows)))
    checks.append(("fig6a unknown-het comm grows with sigma^2",
                   last["comm_unknown"] > first["comm_unknown"]))
    checks.append(("fig6b iterations grow with sigma^2 (unknown)",
                   last["iters_unknown"] >= first["iters_unknown"]))
    checks.append(("fig6b known <= unknown iterations at high sigma^2",
                   last["iters_known"] <= last["iters_unknown"] + 1))
    return checks
