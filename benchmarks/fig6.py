"""Paper Figures 6a/6b: extra communication N_comm/N and reassignment
iterations I versus heterogeneity variance sigma^2, for work exchange
with and without heterogeneity knowledge (mu = 50, K = 50, N = 1e6).

One declarative ``ExperimentSpec``: the (sigma^2 x heterogeneity-draw)
scenario grid plus two scheme tasks (known / unknown variant), both
seeded at 2024 so the numpy-backend numbers are seed-for-seed
bit-identical to the pre-spec driver.  The sampler backend and device
sharding ride on the spec; ``store=`` lands the result in the
content-addressed store."""
from __future__ import annotations

import numpy as np

from repro.experiments import (ExperimentResult, ExperimentSpec,
                               ScenarioGrid, run_experiment, scheme_spec)
from .common import HET_DRAWS, K_PAPER, N_PAPER, THRESHOLD_FRAC

MU = 50.0
SIGMA2S = (0.0, 166.0, 333.0, 500.0, 666.0, 833.0)   # up to mu^2/3

VARIANTS = (("known", "work_exchange"), ("unknown", "work_exchange_unknown"))


def experiment(n: int = N_PAPER, draws: int = HET_DRAWS, trials: int = 4,
               quick: bool = False,
               backend: str | None = None) -> ExperimentSpec:
    sigma2s = SIGMA2S[::2] if quick else SIGMA2S
    n_draws = max(4, draws // 4) if quick else draws
    # the full grid is (sigma^2 x draw): one spec per cell, grid-major
    points = [(MU, sigma2, 1000 + d)
              for sigma2 in sigma2s for d in range(n_draws)]
    return ExperimentSpec(
        name="fig6-quick" if quick else "fig6",
        grid=ScenarioGrid(K=K_PAPER, points=points),
        schemes=tuple(scheme_spec(name, key=label,
                                  threshold_frac=THRESHOLD_FRAC)
                      for label, name in VARIANTS),
        N=n, trials=trials, seed=2024, backend=backend)


def rows_from(result: ExperimentResult):
    n = result.spec.N
    sigma2s = sorted({s2 for _, s2, _ in result.spec.grid.points})
    n_draws = len(result.spec.grid) // len(sigma2s)
    rows = []
    for i, sigma2 in enumerate(sigma2s):
        cell = slice(i * n_draws, (i + 1) * n_draws)
        comm = {lbl: np.array([r.n_comm / n
                               for r in result.report(lbl)[cell]])
                for lbl, _ in VARIANTS}
        iters = {lbl: np.array([r.iterations
                                for r in result.report(lbl)[cell]])
                 for lbl, _ in VARIANTS}
        rows.append({
            "sigma2": sigma2,
            "comm_known": float(comm["known"].mean()),
            "comm_known_std": float(comm["known"].std()),
            "comm_unknown": float(comm["unknown"].mean()),
            "comm_unknown_std": float(comm["unknown"].std()),
            "iters_known": float(iters["known"].mean()),
            "iters_unknown": float(iters["unknown"].mean()),
        })
    return rows


def run(n: int = N_PAPER, draws: int = HET_DRAWS, trials: int = 4,
        quick: bool = False, backend: str | None = None, store=None,
        force: bool = False):
    result = run_experiment(experiment(n, draws, trials, quick, backend),
                            store=store, force=force)
    return rows_from(result)


def validate(rows) -> list[str]:
    checks = []
    first, last = rows[0], rows[-1]
    checks.append(("fig6a known-het comm ~ 0 at every sigma^2",
                   all(r["comm_known"] < 0.02 for r in rows)))
    checks.append(("fig6a unknown-het comm grows with sigma^2",
                   last["comm_unknown"] > first["comm_unknown"]))
    checks.append(("fig6b iterations grow with sigma^2 (unknown)",
                   last["iters_unknown"] >= first["iters_unknown"]))
    checks.append(("fig6b known <= unknown iterations at high sigma^2",
                   last["iters_known"] <= last["iters_unknown"] + 1))
    return checks
