"""Paper Figures 6a/6b: extra communication N_comm/N and reassignment
iterations I versus heterogeneity variance sigma^2, for work exchange
with and without heterogeneity knowledge (mu = 50, K = 50, N = 1e6).

Both variants are resolved through the scheme registry; the vectorized
MC engine makes the trials dimension free."""
from __future__ import annotations

import numpy as np

from repro.core.schemes import get_scheme
from .common import HET_DRAWS, N_PAPER, THRESHOLD_FRAC, make_het

MU = 50.0
SIGMA2S = (0.0, 166.0, 333.0, 500.0, 666.0, 833.0)   # up to mu^2/3

VARIANTS = (("known", "work_exchange"), ("unknown", "work_exchange_unknown"))


def run(n: int = N_PAPER, draws: int = HET_DRAWS, trials: int = 4,
        quick: bool = False):
    rows = []
    sigma2s = SIGMA2S[::2] if quick else SIGMA2S
    schemes = {label: get_scheme(name, threshold_frac=THRESHOLD_FRAC)
               for label, name in VARIANTS}
    for sigma2 in sigma2s:
        acc = {(lbl, met): [] for lbl, _ in VARIANTS
               for met in ("comm", "iters")}
        for d in range(draws if not quick else max(4, draws // 4)):
            het = make_het(MU, sigma2, seed=1000 + d)
            rng = np.random.default_rng(d)
            for label, scheme in schemes.items():
                rep = scheme.mc(het, n, trials=trials, rng=rng)
                acc[(label, "comm")].append(rep.n_comm / n)
                acc[(label, "iters")].append(rep.iterations)
        rows.append({
            "sigma2": sigma2,
            "comm_known": float(np.mean(acc[("known", "comm")])),
            "comm_known_std": float(np.std(acc[("known", "comm")])),
            "comm_unknown": float(np.mean(acc[("unknown", "comm")])),
            "comm_unknown_std": float(np.std(acc[("unknown", "comm")])),
            "iters_known": float(np.mean(acc[("known", "iters")])),
            "iters_unknown": float(np.mean(acc[("unknown", "iters")])),
        })
    return rows


def validate(rows) -> list[str]:
    checks = []
    first, last = rows[0], rows[-1]
    checks.append(("fig6a known-het comm ~ 0 at every sigma^2",
                   all(r["comm_known"] < 0.02 for r in rows)))
    checks.append(("fig6a unknown-het comm grows with sigma^2",
                   last["comm_unknown"] > first["comm_unknown"]))
    checks.append(("fig6b iterations grow with sigma^2 (unknown)",
                   last["iters_unknown"] >= first["iters_unknown"]))
    checks.append(("fig6b known <= unknown iterations at high sigma^2",
                   last["iters_known"] <= last["iters_unknown"] + 1))
    return checks
