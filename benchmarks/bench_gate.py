"""CI benchmark-regression gate over ``results/BENCH_schemes.json``.

Compares a freshly generated benchmark json against the committed
baseline and fails (exit 1) on

* **wall-clock regression > 25%** after machine-speed normalization: raw
  wall-clocks are not comparable across runner generations, so every
  wall ratio is divided by the median ratio over all timed entries (the
  machine calibration factor); what remains is per-entry drift.  Entries
  faster than ``--min-wall`` seconds in the baseline are reported but
  not gated (timer noise); wall gating is skipped entirely when the two
  runs used different global configs (quick vs full).  Residual risk:
  a runner whose numpy-vs-jax relative speed differs sharply from the
  baseline machine shows up as per-entry drift -- the walls in the json
  are min-of-reps to keep jitter out, and ``--wall-tol`` widens the
  band when a runner generation change lands.
* **mean T_comp drift beyond Monte-Carlo tolerance**: both runs use
  fixed seeds, so per-scheme means should agree to ~5 combined standard
  errors (numpy backends are bit-reproducible; the tolerance absorbs
  numpy-version and platform differences).

A before/after markdown table goes to ``$GITHUB_STEP_SUMMARY`` when set
(always to stdout), so the regression picture is one click away in CI.

Usage:
    python -m benchmarks.bench_gate --baseline results/BENCH_schemes.json \
        --current /tmp/fresh.json [--wall-tol 0.25] [--min-wall 0.02]
"""
from __future__ import annotations

import argparse
import json
import os
import statistics
import sys
from pathlib import Path

WALL_KEYS_GRID = ("pr1_numpy_loop_s", "numpy_grid_s", "jax_grid_s",
                  "pallas_grid_s")
WALL_KEYS_MDS = ("pr2_loop_s", "numpy_grid_s", "jax_grid_s",
                 "pallas_grid_s")
WALL_KEYS_SHARDED = ("single_jax_s", "sharded_jax_s")
WALL_KEYS_DRIFTING = ("numpy_grid_s", "jax_grid_s", "pallas_grid_s")
WALL_KEYS_PANEL = ("per_scheme_jax_s", "fused_jax_s",
                   "per_scheme_pallas_s", "fused_pallas_s")
WALL_KEYS_SERVE = ("engine_wall_s",)
WALL_KEYS_SERVE_SCAN = ("numpy_sweep_s", "jax_sweep_s",
                        "jax_first_call_s")
WALL_KEYS_JAX_CACHE = ("cold_first_call_s", "cold_second_shape_s",
                       "warm_first_call_s", "warm_second_shape_s")
# episode wall is pinned by LiveConfig.target_wall_s (time-scale solved),
# so drift here means the coordinator itself got slower; the pure
# coordination wall is tiny and usually falls under --min-wall (reported,
# not gated)
WALL_KEYS_CONTROL = ("episode_wall_s", "coordination_wall_s")
WALL_KEYS_TRAIN = ("per_unit_loop_s", "scan_engine_s")


def load(path: str) -> dict:
    return json.loads(Path(path).read_text())


def collect_walls(report: dict) -> dict:
    """name -> wall seconds, over schemes + engine + grid sections."""
    walls = {}
    for name, entry in report.get("schemes", {}).items():
        walls[f"schemes.{name}"] = float(entry["wall_s"])
    eng = report.get("mc_engine", {})
    if "vectorized_s" in eng:
        walls["mc_engine.vectorized_s"] = float(eng["vectorized_s"])
    grid = report.get("fig5_grid", {})
    for key in WALL_KEYS_GRID:
        if key in grid:
            walls[f"fig5_grid.{key}"] = float(grid[key])
    mds = report.get("mds_grid", {})
    for key in WALL_KEYS_MDS:
        if key in mds:
            walls[f"mds_grid.{key}"] = float(mds[key])
    sharded = report.get("fig5_sharded", {})
    # only comparable when both runs saw the same device count
    for key in WALL_KEYS_SHARDED:
        if key in sharded:
            walls[f"fig5_sharded.{key}@{sharded.get('devices')}dev"] = \
                float(sharded[key])
    drifting = report.get("fig5_drifting", {})
    for key in WALL_KEYS_DRIFTING:
        if key in drifting:
            walls[f"fig5_drifting.{key}"] = float(drifting[key])
    panel = report.get("panel", {})
    for key in WALL_KEYS_PANEL:
        if key in panel:
            walls[f"panel.{key}"] = float(panel[key])
    serve = report.get("serve_load", {})
    for key in WALL_KEYS_SERVE:
        if key in serve:
            walls[f"serve_load.{key}"] = float(serve[key])
    serve_scan = report.get("serve_scan", {})
    for key in WALL_KEYS_SERVE_SCAN:
        if key in serve_scan:
            walls[f"serve_scan.{key}"] = float(serve_scan[key])
    # the sharded sweep wall is only comparable at equal device counts
    if "sharded_jax_sweep_s" in serve_scan:
        walls[(f"serve_scan.sharded_jax_sweep_s"
               f"@{serve_scan.get('sharded_devices')}dev")] = \
            float(serve_scan["sharded_jax_sweep_s"])
    jax_cache = report.get("jax_cache", {})
    for key in WALL_KEYS_JAX_CACHE:
        if key in jax_cache:
            walls[f"jax_cache.{key}"] = float(jax_cache[key])
    control = report.get("control_plane", {})
    for key in WALL_KEYS_CONTROL:
        if key in control:
            walls[f"control_plane.{key}"] = float(control[key])
    train = report.get("train", {})
    for key in WALL_KEYS_TRAIN:
        if key in train:
            walls[f"train.{key}"] = float(train[key])
    return walls


def gate(baseline: dict, current: dict, wall_tol: float, min_wall: float,
         se_tol: float = 5.0):
    failures, rows = [], []

    # --- wall-clock, machine-speed normalized ---------------------------
    # quick-mode and full-mode runs do different amounts of work: wall
    # gating only makes sense between runs of the same global config
    same_config = (baseline.get("config") == current.get("config"))
    if not same_config:
        rows.append(("(wall gating)", str(baseline.get("config")),
                     str(current.get("config")), "config mismatch", "skip"))
    base_w = collect_walls(baseline) if same_config else {}
    cur_w = collect_walls(current) if same_config else {}
    shared = [k for k in base_w if k in cur_w and base_w[k] > 0]
    ratios = {k: cur_w[k] / base_w[k] for k in shared}
    sizable = [r for k, r in ratios.items() if base_w[k] >= min_wall]
    calib = statistics.median(sizable) if sizable else 1.0
    for k in sorted(shared):
        drift = ratios[k] / calib
        gated = base_w[k] >= min_wall
        ok = (not gated) or drift <= 1.0 + wall_tol
        if not ok:
            failures.append(f"wall regression {k}: {base_w[k]:.3f}s -> "
                            f"{cur_w[k]:.3f}s ({drift:.2f}x normalized, "
                            f"tol {1 + wall_tol:.2f}x)")
        rows.append((k, f"{base_w[k]:.4f}s", f"{cur_w[k]:.4f}s",
                     f"{drift:.2f}x" + ("" if gated else " (ungated)"),
                     "FAIL" if not ok else "ok"))

    # --- mean T_comp drift vs MC tolerance ------------------------------
    for name, base in sorted(baseline.get("schemes", {}).items()):
        cur = current.get("schemes", {}).get(name)
        if cur is None:
            failures.append(f"scheme {name!r} present in baseline but "
                            f"missing from the current run")
            rows.append((f"schemes.{name}.t_comp",
                         f"{base['t_comp_mean']:.4f}", "MISSING", "-",
                         "FAIL"))
            continue
        if (base.get("N") != cur.get("N")
                or base.get("trials") != cur.get("trials")):
            rows.append((f"schemes.{name}.t_comp",
                         f"{base['t_comp_mean']:.4f}",
                         f"{cur['t_comp_mean']:.4f}",
                         "config changed", "skip"))
            continue
        se = ((base["t_comp_std"] ** 2 / max(base["trials"], 1)
               + cur["t_comp_std"] ** 2 / max(cur["trials"], 1)) ** 0.5)
        tol = max(se_tol * se, 1e-9 + 1e-6 * abs(base["t_comp_mean"]))
        drift = abs(cur["t_comp_mean"] - base["t_comp_mean"])
        ok = drift <= tol
        if not ok:
            failures.append(f"T_comp drift {name}: "
                            f"{base['t_comp_mean']:.4f} -> "
                            f"{cur['t_comp_mean']:.4f} "
                            f"(|drift| {drift:.4g} > tol {tol:.4g})")
        rows.append((f"schemes.{name}.t_comp", f"{base['t_comp_mean']:.4f}",
                     f"{cur['t_comp_mean']:.4f}",
                     f"{drift / se:.1f} se" if se > 0 else "exact",
                     "FAIL" if not ok else "ok"))

    return failures, rows, calib


def markdown_table(rows, calib: float, failures) -> str:
    lines = ["# Benchmark gate",
             "",
             f"Machine calibration (median wall ratio): `{calib:.2f}x`",
             "",
             "| metric | baseline | current | drift | status |",
             "|---|---|---|---|---|"]
    lines += [f"| {m} | {b} | {c} | {d} | {s} |" for m, b, c, d, s in rows]
    lines.append("")
    lines.append(f"**{'FAIL' if failures else 'PASS'}** -- "
                 f"{len(failures)} regression(s)")
    lines += [f"- {f}" for f in failures]
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--wall-tol", type=float, default=0.25,
                    help="allowed normalized wall-clock regression (0.25 "
                         "= 25%%)")
    ap.add_argument("--min-wall", type=float, default=0.02,
                    help="baseline walls below this many seconds are "
                         "reported but not gated (timer noise)")
    args = ap.parse_args(argv)

    failures, rows, calib = gate(load(args.baseline), load(args.current),
                                 args.wall_tol, args.min_wall)
    table = markdown_table(rows, calib, failures)
    print(table)
    summary = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary:
        with open(summary, "a") as f:
            f.write(table + "\n")
    if failures:
        print(f"\nbench-gate: FAIL ({len(failures)} regression(s))",
              file=sys.stderr)
        return 1
    print("\nbench-gate: PASS", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
