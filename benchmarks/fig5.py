"""Paper Figure 5: mean computation time of all five schemes.

N = 1e6 points over K = 50 workers, four values of mu-hat = lambda_sum/K,
two heterogeneity levels (sigma^2 = 0 and mu^2/6).  Schemes: optimized
MDS (eq. 6), oracle bound (Thm 1), heterogeneity-aware fixed assignment
(Sec. 5.1), work exchange known (Sec. 5.2) / unknown (Sec. 6).
"""
from __future__ import annotations

import numpy as np

from repro.core import simulator
from .common import (HET_DRAWS, K_PAPER, N_PAPER, TRIALS, make_het, we_cfg)

MUS = (10.0, 20.0, 50.0, 100.0)


def run(trials: int = TRIALS, n: int = N_PAPER, quick: bool = False):
    rows = []
    mus = MUS[:2] if quick else MUS
    for mu in mus:
        for sig_label, sigma2 in (("0", 0.0), ("mu^2/6", mu * mu / 6)):
            het = make_het(mu, sigma2, seed=int(mu))
            rng = np.random.default_rng(1234)
            oracle_t = n / het.lambda_sum
            l_star, mds_t = simulator.mds_optimize(
                het, n, max(8, trials // 2), rng)
            fixed_t = simulator.fixed_mean_time(het, n, trials, rng)
            we_k = simulator.work_exchange_mc(het, n, we_cfg(True),
                                              trials, rng)
            we_u = simulator.work_exchange_mc(het, n, we_cfg(False),
                                              trials, rng)
            rows.append({
                "mu": mu, "sigma2": sig_label,
                "lambda_sum": het.lambda_sum,
                "oracle": oracle_t, "mds_opt": mds_t, "mds_L": l_star,
                "fixed": fixed_t, "we_known": we_k.t_comp,
                "we_unknown": we_u.t_comp,
            })
    return rows


def validate(rows) -> list[str]:
    """Paper claims checked against our reproduction."""
    checks = []
    for r in rows:
        ok = r["we_known"] <= 1.05 * r["oracle"]
        checks.append((f"fig5[mu={r['mu']},s2={r['sigma2']}] "
                       f"WE-known within 5% of oracle", ok))
        ok = r["we_unknown"] <= 1.10 * r["oracle"]
        checks.append((f"fig5[mu={r['mu']},s2={r['sigma2']}] "
                       f"WE-unknown within 10% of oracle", ok))
        if r["sigma2"] != "0":
            ok = r["mds_opt"] >= r["we_known"]
            checks.append((f"fig5[mu={r['mu']}] MDS >= WE at high sigma^2",
                           ok))
        ok = r["fixed"] >= r["oracle"] * 0.999
        checks.append((f"fig5[mu={r['mu']},s2={r['sigma2']}] "
                       f"fixed >= oracle", ok))
    return checks
