"""Paper Figure 5: mean computation time of all five schemes.

N = 1e6 points over K = 50 workers, four values of mu-hat = lambda_sum/K,
two heterogeneity levels (sigma^2 = 0 and mu^2/6).  Every scheme is
resolved through ``SCHEME_REGISTRY`` -- register a scheme and add its
name to ``benchmarks.common.FIG_SCHEMES`` and it appears in this figure
(and the BENCH json) with no further wiring.

The whole (mu, sigma^2) panel goes through ``Scheme.mc_grid`` -- one
engine dispatch per scheme for the full grid instead of a Python loop of
``mc()`` calls -- and inherits the sampler backend from
``REPRO_SAMPLER_BACKEND`` (or the ``backend=`` argument).
"""
from __future__ import annotations

import numpy as np

from .common import N_PAPER, TRIALS, make_het, scheme_panel

MUS = (10.0, 20.0, 50.0, 100.0)
SIGMA_LEVELS = (("0", 0.0), ("mu^2/6", 1.0 / 6.0))   # sigma2 = frac * mu^2


def grid_points(quick: bool = False):
    """The figure's (mu, sigma^2-label, sigma^2) axis, panel order."""
    mus = MUS[:2] if quick else MUS
    return [(mu, lbl, frac * mu * mu) for mu in mus
            for lbl, frac in SIGMA_LEVELS]


def grid_specs(quick: bool = False):
    """One ``HetSpec`` per panel point (seeded per mu, as in PR 1)."""
    return [make_het(mu, sigma2, seed=int(mu))
            for mu, _, sigma2 in grid_points(quick)]


def run(trials: int = TRIALS, n: int = N_PAPER, quick: bool = False,
        backend: str | None = None):
    points = grid_points(quick)
    specs = grid_specs(quick)
    rows = [{"mu": mu, "sigma2": lbl, "lambda_sum": het.lambda_sum,
             "oracle": n / het.lambda_sum}
            for (mu, lbl, _), het in zip(points, specs)]
    for name, scheme in scheme_panel().items():
        reports = scheme.mc_grid(specs, n, trials=trials,
                                 rng=np.random.default_rng(1234),
                                 backend=backend)
        for row, rep in zip(rows, reports):
            row[name] = rep.t_comp
            if "L" in rep.extra:
                row[f"{name}_L"] = int(rep.extra["L"])
    for row in rows:
        # legacy column names kept for CSV consumers (only for panel
        # members actually present, so trimming FIG_SCHEMES stays safe)
        for old, new in (("mds_opt", "mds"), ("we_known", "work_exchange"),
                         ("we_unknown", "work_exchange_unknown")):
            if new in row:
                row[old] = row[new]
    return rows


def validate(rows) -> list[str]:
    """Paper claims checked against our reproduction."""
    checks = []
    for r in rows:
        ok = r["we_known"] <= 1.05 * r["oracle"]
        checks.append((f"fig5[mu={r['mu']},s2={r['sigma2']}] "
                       f"WE-known within 5% of oracle", ok))
        ok = r["we_unknown"] <= 1.10 * r["oracle"]
        checks.append((f"fig5[mu={r['mu']},s2={r['sigma2']}] "
                       f"WE-unknown within 10% of oracle", ok))
        if r["sigma2"] != "0":
            ok = r["mds_opt"] >= r["we_known"]
            checks.append((f"fig5[mu={r['mu']}] MDS >= WE at high sigma^2",
                           ok))
        ok = r["fixed"] >= r["oracle"] * 0.999
        checks.append((f"fig5[mu={r['mu']},s2={r['sigma2']}] "
                       f"fixed >= oracle", ok))
    return checks
