"""Paper Figure 5: mean computation time of all registered panel schemes.

N = 1e6 points over K = 50 workers, four values of mu-hat = lambda_sum/K,
two heterogeneity levels (sigma^2 = 0 and mu^2/6).

The whole study is ONE declarative ``ExperimentSpec`` resolved through
``repro.experiments``: the scheme panel (``benchmarks.common.FIG_SCHEMES``
-- register a scheme, add its name, and it appears here and in the BENCH
json), the (mu, sigma^2) ``ScenarioGrid`` with per-mu pinned
heterogeneity draws, and the execution knobs (sampler backend, device
sharding).  Each scheme task draws from its own fresh
``default_rng(1234)``, so the numpy-backend numbers are seed-for-seed
bit-identical to the pre-spec drivers (pinned by
``tests/test_experiments.py``).  Pass ``store=`` to land the result in
the content-addressed store and make unchanged re-runs cache hits.
"""
from __future__ import annotations

from repro.experiments import (ExperimentResult, ExperimentSpec,
                               ScenarioGrid, run_experiment, scheme_spec)
from .common import FIG_SCHEMES, K_PAPER, N_PAPER, TRIALS, make_het

MUS = (10.0, 20.0, 50.0, 100.0)
SIGMA_LEVELS = (("0", 0.0), ("mu^2/6", 1.0 / 6.0))   # sigma2 = frac * mu^2


def grid_points(quick: bool = False):
    """The figure's (mu, sigma^2-label, sigma^2) axis, panel order."""
    mus = MUS[:2] if quick else MUS
    return [(mu, lbl, frac * mu * mu) for mu in mus
            for lbl, frac in SIGMA_LEVELS]


def grid_specs(quick: bool = False):
    """One ``HetSpec`` per panel point (seeded per mu, as in PR 1)."""
    return [make_het(mu, sigma2, seed=int(mu))
            for mu, _, sigma2 in grid_points(quick)]


def experiment(trials: int = TRIALS, n: int = N_PAPER, quick: bool = False,
               backend: str | None = None, devices: int | str = 1,
               panel: str = "per_scheme") -> ExperimentSpec:
    """The figure as a declarative spec (same draws as ``grid_specs``).

    ``panel="fused"`` routes the work-exchange known/unknown pair
    through the fused whole-panel dispatch (one engine call on jax /
    pallas); every other scheme keeps its per-task stream bit-identical.
    """
    points = [(mu, sigma2, int(mu)) for mu, _, sigma2 in grid_points(quick)]
    return ExperimentSpec(
        name="fig5-quick" if quick else "fig5",
        grid=ScenarioGrid(K=K_PAPER, points=points),
        schemes=tuple(scheme_spec(name) for name in FIG_SCHEMES),
        N=n, trials=trials, seed=1234, backend=backend, devices=devices,
        panel=panel)


def drifting_experiment(trials: int = TRIALS, n: int = N_PAPER,
                        quick: bool = False, backend: str | None = None,
                        kind: str = "ar1",
                        panel: str = "per_scheme") -> ExperimentSpec:
    """The fig5 panel under drifting heterogeneity: same ``(mu,
    sigma^2)`` points, but the rates evolve across exchange rounds
    (``repro.scenarios.DriftingScenario``) -- the stress test of the
    unknown-heterogeneity claim that a once-drawn grid cannot provide.
    Only the exchange schemes appear: they are the ones whose inner
    loop consumes the per-round schedule.
    """
    from repro.scenarios import DriftingScenario
    points = [(mu, sigma2, int(mu)) for mu, _, sigma2 in grid_points(quick)]
    return ExperimentSpec(
        name="fig5-drifting-quick" if quick else "fig5-drifting",
        grid=DriftingScenario(K=K_PAPER, points=tuple(points), kind=kind,
                              rounds=48),
        schemes=(scheme_spec("work_exchange"),
                 scheme_spec("work_exchange_unknown")),
        N=n, trials=trials, seed=1234, backend=backend, panel=panel)


def rows_from(result: ExperimentResult):
    """Legacy row dicts (CSV schema) from an experiment result."""
    points = result.spec.grid.points
    hets = result.spec.grid.specs()
    n = result.spec.N
    rows = [{"mu": mu, "sigma2": "0" if sigma2 == 0 else "mu^2/6",
             "lambda_sum": het.lambda_sum, "oracle": n / het.lambda_sum}
            for (mu, sigma2, _), het in zip(points, hets)]
    for name in result.keys():
        for row, rep in zip(rows, result.report(name)):
            row[name] = rep.t_comp
            if "L" in rep.extra:
                row[f"{name}_L"] = int(rep.extra["L"])
    for row in rows:
        # legacy column names kept for CSV consumers (only for panel
        # members actually present, so trimming FIG_SCHEMES stays safe)
        for old, new in (("mds_opt", "mds"), ("we_known", "work_exchange"),
                         ("we_unknown", "work_exchange_unknown")):
            if new in row:
                row[old] = row[new]
    return rows


def run(trials: int = TRIALS, n: int = N_PAPER, quick: bool = False,
        backend: str | None = None, store=None, force: bool = False):
    result = run_experiment(experiment(trials, n, quick, backend),
                            store=store, force=force)
    return rows_from(result)


def validate(rows) -> list[str]:
    """Paper claims checked against our reproduction."""
    checks = []
    for r in rows:
        ok = r["we_known"] <= 1.05 * r["oracle"]
        checks.append((f"fig5[mu={r['mu']},s2={r['sigma2']}] "
                       f"WE-known within 5% of oracle", ok))
        ok = r["we_unknown"] <= 1.10 * r["oracle"]
        checks.append((f"fig5[mu={r['mu']},s2={r['sigma2']}] "
                       f"WE-unknown within 10% of oracle", ok))
        if r["sigma2"] != "0":
            ok = r["mds_opt"] >= r["we_known"]
            checks.append((f"fig5[mu={r['mu']}] MDS >= WE at high sigma^2",
                           ok))
        ok = r["fixed"] >= r["oracle"] * 0.999
        checks.append((f"fig5[mu={r['mu']},s2={r['sigma2']}] "
                       f"fixed >= oracle", ok))
    return checks
