"""Paper Figure 5: mean computation time of all five schemes.

N = 1e6 points over K = 50 workers, four values of mu-hat = lambda_sum/K,
two heterogeneity levels (sigma^2 = 0 and mu^2/6).  Every scheme is
resolved through ``SCHEME_REGISTRY`` -- register a scheme and add its
name to ``benchmarks.common.FIG_SCHEMES`` and it appears in this figure
(and the BENCH json) with no further wiring.
"""
from __future__ import annotations

import numpy as np

from .common import N_PAPER, TRIALS, make_het, scheme_panel

MUS = (10.0, 20.0, 50.0, 100.0)


def run(trials: int = TRIALS, n: int = N_PAPER, quick: bool = False):
    rows = []
    mus = MUS[:2] if quick else MUS
    for mu in mus:
        for sig_label, sigma2 in (("0", 0.0), ("mu^2/6", mu * mu / 6)):
            het = make_het(mu, sigma2, seed=int(mu))
            rng = np.random.default_rng(1234)
            row = {"mu": mu, "sigma2": sig_label,
                   "lambda_sum": het.lambda_sum,
                   "oracle": n / het.lambda_sum}
            for name, scheme in scheme_panel().items():
                rep = scheme.mc(het, n, trials=rep_trials(name, trials),
                                rng=rng)
                row[name] = rep.t_comp
                if "L" in rep.extra:
                    row[f"{name}_L"] = int(rep.extra["L"])
            # legacy column names kept for CSV consumers (only for panel
            # members actually present, so trimming FIG_SCHEMES stays safe)
            for old, new in (("mds_opt", "mds"), ("we_known", "work_exchange"),
                             ("we_unknown", "work_exchange_unknown")):
                if new in row:
                    row[old] = row[new]
            rows.append(row)
    return rows


def rep_trials(name: str, trials: int) -> int:
    # the MDS L-sweep draws trials per candidate L; keep its budget at the
    # pre-registry level (mds_optimize used trials // 2)
    return max(8, trials // 2) if name == "mds" else trials


def validate(rows) -> list[str]:
    """Paper claims checked against our reproduction."""
    checks = []
    for r in rows:
        ok = r["we_known"] <= 1.05 * r["oracle"]
        checks.append((f"fig5[mu={r['mu']},s2={r['sigma2']}] "
                       f"WE-known within 5% of oracle", ok))
        ok = r["we_unknown"] <= 1.10 * r["oracle"]
        checks.append((f"fig5[mu={r['mu']},s2={r['sigma2']}] "
                       f"WE-unknown within 10% of oracle", ok))
        if r["sigma2"] != "0":
            ok = r["mds_opt"] >= r["we_known"]
            checks.append((f"fig5[mu={r['mu']}] MDS >= WE at high sigma^2",
                           ok))
        ok = r["fixed"] >= r["oracle"] * 0.999
        checks.append((f"fig5[mu={r['mu']},s2={r['sigma2']}] "
                       f"fixed >= oracle", ok))
    return checks
