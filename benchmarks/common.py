"""Shared benchmark configuration (paper Section 7 settings)."""
from __future__ import annotations

import numpy as np

from repro.core.types import ExchangeConfig, HetSpec

# paper: N = 1e6 points, K = 50 workers, threshold 0.01 * N/K
N_PAPER = 1_000_000
K_PAPER = 50
THRESHOLD_FRAC = 0.01

# Monte-Carlo budget (paper uses 50 heterogeneity draws per point)
TRIALS = 20
HET_DRAWS = 20


def make_het(mu: float, sigma2: float, seed: int) -> HetSpec:
    return HetSpec.uniform_random(K_PAPER, mu, sigma2,
                                  np.random.default_rng(seed))


def we_cfg(known: bool, threshold_frac: float = THRESHOLD_FRAC
           ) -> ExchangeConfig:
    return ExchangeConfig(known_heterogeneity=known,
                          threshold_frac=threshold_frac)
