"""Shared benchmark configuration (paper Section 7 settings)."""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.schemes import Scheme, get_scheme
from repro.core.types import ExchangeConfig, HetSpec

# paper: N = 1e6 points, K = 50 workers, threshold 0.01 * N/K
N_PAPER = 1_000_000
K_PAPER = 50
THRESHOLD_FRAC = 0.01

# Monte-Carlo budget (paper uses 50 heterogeneity draws per point)
TRIALS = 20
HET_DRAWS = 20


def make_het(mu: float, sigma2: float, seed: int) -> HetSpec:
    return HetSpec.uniform_random(K_PAPER, mu, sigma2,
                                  np.random.default_rng(seed))


def we_cfg(known: bool, threshold_frac: float = THRESHOLD_FRAC
           ) -> ExchangeConfig:
    return ExchangeConfig(known_heterogeneity=known,
                          threshold_frac=threshold_frac)


# registry-resolved scheme panel shared by the figure drivers; extend this
# tuple (or register a new scheme) and it shows up in fig5 + the BENCH json
FIG_SCHEMES = ("mds", "fixed", "work_exchange", "work_exchange_unknown",
               "het_mds", "hedged")


def scheme_panel() -> Dict[str, Scheme]:
    """name -> configured Scheme instance for the figure sweeps."""
    return {name: get_scheme(name) for name in FIG_SCHEMES}
