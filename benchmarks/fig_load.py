"""Load-vs-latency curves: every scheme as a dispatch policy under
streaming arrivals -- the figure family the paper does not have.

The paper's figures answer "one batch of N units: how long?"; this one
answers the serving question behind the north star: jobs arrive
continuously at a swept fraction of the cluster's aggregate capacity,
and each scheme -- recast as a dispatch policy by ``repro.serving`` --
trades p50/p99 sojourn, sustainable throughput, and SLO misses
differently as the load approaches saturation.  Two scenarios share one
operating point (K=16, mu=30, sigma^2=mu^2/6): ``stationary`` (rates
pinned) and ``drifting`` (AR(1) rate schedule moving the TRUE service
rates under every policy while placement still believes the nominal
ones -- except ``work_exchange_unknown``, which follows its online
estimates).

Like every figure driver, the study is one declarative
``ExperimentSpec`` (per scenario) through ``repro.experiments`` and the
content-addressed store; ``validate`` checks the queueing-theory shape
(latency monotone in load, percentile ordering, throughput tracking
offered load below the knee, the under-provisioned coded scheme
saturating first) rather than paper claims.
"""
from __future__ import annotations

from repro.experiments import (ExperimentResult, ExperimentSpec,
                               ScenarioGrid, ServingConfig, run_experiment,
                               scheme_spec)

# the dispatch-policy panel: exchange, static, coded, replicated
SERVE_SCHEMES = ("work_exchange", "work_exchange_unknown", "fixed",
                 "het_mds", "hedged")
LOADS = (0.5, 0.7, 0.85, 0.95)
LOADS_QUICK = (0.6, 0.9)

K_SERVE = 16
MU = 30.0
SIGMA2 = MU * MU / 6.0
HET_SEED = 7
N_SERVE = 150          # units per job (mean service ~N/lambda_sum sec)
TRIALS = 16
DEADLINE_SLO = 4.0     # in multiples of the pooled ideal sojourn


def serving_config(quick: bool = False) -> ServingConfig:
    return ServingConfig(loads=LOADS_QUICK if quick else LOADS,
                         slots=400 if quick else 2000,
                         deadline_slo=DEADLINE_SLO)


def experiment(trials: int = TRIALS, quick: bool = False,
               scenario: str = "stationary") -> ExperimentSpec:
    """The load sweep as a declarative spec, one per scenario."""
    point = (MU, SIGMA2, HET_SEED)
    if scenario == "stationary":
        grid = ScenarioGrid(K=K_SERVE, points=[point])
    elif scenario == "drifting":
        from repro.scenarios import DriftingScenario
        grid = DriftingScenario(K=K_SERVE, points=(point,), kind="ar1",
                                rounds=24)
    else:
        raise ValueError(f"unknown fig_load scenario {scenario!r}")
    tag = "-quick" if quick else ""
    return ExperimentSpec(
        name=f"fig-load-{scenario}{tag}",
        grid=grid,
        schemes=tuple(scheme_spec(name) for name in SERVE_SCHEMES),
        N=N_SERVE, trials=(6 if quick else trials), seed=1234,
        serving=serving_config(quick))


def rows_from(result: ExperimentResult):
    """Flat row dicts, one per (scheme, load): the figure's data table."""
    spec = result.spec
    scenario = ("drifting" if spec.grid.family == "drifting"
                else "stationary")
    lam_sum = spec.grid.specs()[0].lambda_sum
    rows = []
    for name in result.keys():
        for rep in result.report(name):
            e = rep.extra
            rows.append({
                "scenario": scenario, "scheme": name,
                "load": e["offered_load"],
                "offered_jobs_per_s": e["offered_load"] * lam_sum / spec.N,
                "sojourn": rep.t_comp, "p50": e["p50"], "p95": e["p95"],
                "p99": e["p99"],
                "throughput_jobs": e["throughput_jobs"],
                "slo_miss": e.get("slo_miss_rate", 0.0),
                "reject": e["reject_rate"],
                "occupancy": e["occupancy"],
                "n_comm": rep.n_comm,
                "latency_censored": e.get("latency_censored", 0.0),
                "censored_frac": e.get("censored_frac", 0.0),
            })
    return rows


def knees(rows, factor: float = 3.0):
    """First swept load where a scheme's sojourn exceeds ``factor`` x its
    own lightest-load sojourn -- the saturation knee (None = no knee
    inside the sweep).

    A latency-censored row (zero completions: the reported sojourn is
    the horizon LOWER BOUND, not a measurement) counts as saturated
    outright -- the true latency is off the top of the window, so
    comparing the bound against ``factor x base`` would under-detect
    exactly the loads that are most saturated.
    """
    out = {}
    by = {}
    for r in rows:
        by.setdefault((r["scenario"], r["scheme"]), []).append(r)
    for key, rs in by.items():
        rs = sorted(rs, key=lambda r: r["load"])
        base = rs[0]["sojourn"]
        out[key] = next((r["load"] for r in rs
                         if r.get("latency_censored")
                         or r["sojourn"] > factor * base), None)
    return out


def run(trials: int = TRIALS, quick: bool = False, store=None,
        force: bool = False):
    rows = []
    for scenario in ("stationary", "drifting"):
        result = run_experiment(experiment(trials, quick, scenario),
                                store=store, force=force)
        rows += rows_from(result)
    return rows


def validate(rows, quick: bool = False) -> list:
    """Queueing-shape checks on the measured curves.

    The strict shape checks (latency monotone in load, throughput
    tracking offered load below the knee, the coded scheme saturating)
    need the full sweep scale -- at the quick smoke scale (400 slots,
    two loads) end-of-horizon censoring dominates, so a quick pass
    keeps only the structural invariants.
    """
    checks = []
    by = {}
    for r in rows:
        by.setdefault((r["scenario"], r["scheme"]), []).append(r)
    for (scen, scheme), rs in sorted(by.items()):
        rs = sorted(rs, key=lambda r: r["load"])
        lo, hi = rs[0], rs[-1]
        tag = f"fig_load[{scen},{scheme}]"
        checks.append((f"{tag} percentile ordering p50<=p95<=p99",
                       all(r["p50"] <= r["p95"] + 1e-12
                           and r["p95"] <= r["p99"] + 1e-12 for r in rs)))
        checks.append((f"{tag} positive latency and throughput at every "
                       f"load",
                       all(r["sojourn"] > 0 and r["throughput_jobs"] > 0
                           for r in rs)))
        if quick:
            continue
        checks.append((f"{tag} sojourn non-decreasing with load (0.98x)",
                       hi["sojourn"] >= 0.98 * lo["sojourn"]))
        checks.append((f"{tag} throughput tracks offered load below knee",
                       lo["throughput_jobs"]
                       >= 0.75 * lo["offered_jobs_per_s"]))
    if quick:
        return checks
    stat = {s: sorted(rs, key=lambda r: r["load"])
            for (scen, s), rs in by.items() if scen == "stationary"}
    if "work_exchange" in stat and "fixed" in stat:
        we = sum(r["sojourn"] for r in stat["work_exchange"])
        fx = sum(r["sojourn"] for r in stat["fixed"])
        checks.append(("fig_load[stationary] work_exchange mean sojourn "
                       "<= 1.10x fixed over the sweep", we <= 1.10 * fx))
    if "het_mds" in stat:
        # redundancy 1.25 burns ~20% of capacity: the coded policy must
        # hit its saturation wall inside the sweep while loads are still
        # feasible for the uncoded ones
        rs = stat["het_mds"]
        checks.append(("fig_load[stationary] het_mds (r=1.25) saturates: "
                       "top-load sojourn >= 1.3x lightest-load",
                       rs[-1]["sojourn"] >= 1.3 * rs[0]["sojourn"]))
    return checks
